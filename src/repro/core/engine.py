"""One searcher handle over every execution path (exported as `repro.api`).

The paper's pipeline (project -> Eq.-1 radius adaptation -> windowed CSR
gather -> re-rank) used to be reachable through four parallel entry points —
`active_search.search/classify`, `core.batched`, `core.exact`,
`core.distributed` — each re-threading the same execution knobs (`backend=`,
`interpret=`, `chunk_size=`) through every signature.  This module collapses
them into a FAISS-style handle:

  plan = ExecutionPlan(backend="pallas", chunk_size=256)
  s = ActiveSearcher.build(points, labels=labels,
                           cfg=GridConfig(n_classes=3), plan=plan)
  res   = s.search(queries, k=11)            # batched SearchResult
  preds = s.classify(queries, k=11)
  cnts  = s.count_at(queries, radii)         # (B, C) circle counts
  s2    = s.with_plan(backend="exact")       # same index, new execution plan
  s3    = s.insert(more_points)              # streaming growth (core/mutable.py)
  live  = s3.delete(stale_ids).snapshot()    # frozen handle, isolated from s3

HOW a search executes lives entirely in the frozen `ExecutionPlan`
(backend name, Pallas interpret override, chunked streaming, donate-able
device placement); WHAT is searched lives in the (index, cfg) pair the
handle carries.  Backends are uniform `BackendImpl` adapters resolved from a
registry (`register_backend`) — `jnp`, `pallas`, `pallas_q8`, `exact`,
`sharded`, and the count-only `pallas_stacked` benchmark baseline ship
registered; new
execution paths (TPU-Mosaic-tuned plans, async/caching) plug in without
widening any signature.

Every backend returns the same batched `SearchResult`; the exact brute-force
comparator's `ExactResult` is folded into it with the paper-stat fields
(radius/iters/converged/truncated) defaulted.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import exact as exact_lib
from repro.core import projection as proj_lib
from repro.core import pyramid as pyr
from repro.core.active_search import SearchResult, _search_jnp, run_chunked
from repro.core.grid import (
    GridConfig,
    GridIndex,
    build_index,
    flatten_pyramid_tiles,
)

_MODES = ("refined", "paper")


# ------------------------------------------------------------------ plan -----


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """HOW a search executes — frozen, hashable, safe as a jit static arg.

    backend:    registered backend name ("jnp" | "pallas" | "pallas_gather"
                | "pallas_q8" | "exact" | "sharded" | anything added via
                `register_backend`).
    interpret:  force/disable Pallas interpret mode (Pallas-backed backends
                only; None = REPRO_PALLAS_INTERPRET).
    chunk_size: stream query batches through fixed-size chunks so every
                kernel invocation keeps ONE static shape / VMEM footprint.
                Bit-identical for any value.
    d_chunk:    cap the per-step feature-dim accumulation of the candidate
                re-rank kernels (Pallas candidate-ranking backends only;
                None = reduce each candidate in ONE step, bit-identical to
                the jnp path).  Setting a cap bounds kernel VMEM for very
                large d at the cost of reassociating the float32 distance
                sums.
    rerank_k:   shortlist depth of the quantized candidate stage (backends
                with `supports_quantized` only, i.e. "pallas_q8"): the int8
                coarse pass keeps the best `rerank_k` rows by approximate
                int32 score, then the exact fp32 re-rank ranks ONLY those.
                None = min(max(4k, 32), window*row_cap) at call time.
                Larger values raise recall and cost more re-rank bandwidth;
                must be >= k (validated at the search call, where k is
                known) and is clamped to window*row_cap.
    device:     optional placement target (jax.Device or Sharding); queries
                are `jax.device_put` there before dispatch.
    donate:     donate the caller's query buffer on placement (serve-scale
                batches avoid a copy; requires `device`).
    adaptive_r0: seed each query's Eq.-1 start radius from the pyramid's
                top levels (`pyramid.seed_radius` — a free local-density
                sketch) instead of the global cfg.r0.  Changes only WHERE
                the radius schedule starts, never what the search returns
                at the radius it converges to; backends that run the Eq.-1
                loop (jnp / pallas / pallas_gather / sharded) support it.
    """

    backend: str = "jnp"
    interpret: bool | None = None
    chunk_size: int | None = None
    d_chunk: int | None = None
    rerank_k: int | None = None
    device: Any = None
    donate: bool = False
    adaptive_r0: bool = False

    def __post_init__(self):
        if self.chunk_size is not None and self.chunk_size <= 0:
            raise ValueError(
                f"chunk_size must be positive, got {self.chunk_size}"
            )
        if self.d_chunk is not None and self.d_chunk <= 0:
            raise ValueError(
                f"d_chunk must be positive, got {self.d_chunk}"
            )
        if self.rerank_k is not None and self.rerank_k <= 0:
            raise ValueError(
                f"rerank_k must be positive, got {self.rerank_k}"
            )
        if self.donate and self.device is None:
            raise ValueError("donate=True needs an ExecutionPlan.device")


# -------------------------------------------------------------- registry -----


@dataclasses.dataclass(frozen=True)
class BackendImpl:
    """Uniform adapter a backend registers.  Each callable takes the
    searcher handle first, so the impl sees (index, cfg, plan) without the
    registry prescribing how they are consumed.

      search(searcher, queries, k, mode)   -> SearchResult   (batched)
      classify(searcher, queries, k, mode) -> (B,) int32
      count_at(searcher, q_grid, radii)    -> (B, C) int32 circle counts

    Any of the three may be None (e.g. `pallas_stacked` is a count-only
    benchmark baseline); the facade raises eagerly when an op is missing.
    `supports_interpret` gates `plan.interpret`; `supports_d_chunk` gates
    `plan.d_chunk` (only backends that run a Pallas candidate re-rank can
    honor the accumulation cap); `supports_adaptive_r0` gates
    `plan.adaptive_r0` (only backends that run the Eq.-1 radius loop can
    seed it).  `requires_mesh` marks backends that only work on a
    `build_sharded` handle (mesh + axis), so eager validators (e.g. serve's
    CLI check) can reject them up front without name-matching.
    `supports_mutation` gates the facade's insert/delete/snapshot mutation
    ops (core/mutable.py deltas on dense handles, distributed.py cell-routed
    deltas on sharded ones): backends that can serve the refreshed snapshot
    declare True; count-only baselines opt out, and eager validators
    (`serve.py --knn-online`) reject them by capability, not name.
    `supports_quantized` gates `plan.rerank_k`: only backends whose
    candidate stage runs the int8 coarse-shortlist -> exact-re-rank path
    ("pallas_q8") have a shortlist depth to set.
    """

    search: Callable[..., SearchResult] | None = None
    classify: Callable[..., jax.Array] | None = None
    count_at: Callable[..., jax.Array] | None = None
    supports_interpret: bool = False
    supports_d_chunk: bool = False
    supports_adaptive_r0: bool = False
    supports_mutation: bool = False
    supports_quantized: bool = False
    requires_mesh: bool = False
    description: str = ""


_REGISTRY: dict[str, BackendImpl] = {}


def register_backend(name: str, impl: BackendImpl) -> None:
    """Register (or replace) an execution backend under `name`."""
    if not isinstance(impl, BackendImpl):
        raise TypeError(f"impl must be a BackendImpl, got {type(impl).__name__}")
    _REGISTRY[name] = impl


def get_backend(name: str) -> BackendImpl:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{sorted(_REGISTRY)}"
        ) from None


def registered_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ------------------------------------------------------------------ handle ---


@dataclasses.dataclass(frozen=True, eq=False)
class ActiveSearcher:
    """The one handle: (index, cfg) = WHAT is searched, plan = HOW.

    Frozen and cheap to re-plan: `with_plan` returns a new handle sharing
    the same index arrays.  `mesh`/`axis` are only set by `build_sharded`
    (the "sharded" backend merges per-shard searchers under shard_map).

    eq=False: the handle wraps jax arrays, so it compares/hashes by
    IDENTITY — pass the hashable `cfg`/`plan` as jit static args, never the
    handle itself.
    """

    index: GridIndex
    cfg: GridConfig
    plan: ExecutionPlan = ExecutionPlan()
    mesh: Any = None
    axis: str | None = None
    # streaming-mutation state (core/mutable.py): None for frozen handles;
    # set by insert/delete so successive mutations reuse the slack layout
    mutable: Any = None

    # -------------------------------------------------------- construction --
    @classmethod
    def build(
        cls,
        points: jax.Array,
        *,
        labels: jax.Array | None = None,
        ids: jax.Array | None = None,
        cfg: GridConfig | None = None,
        plan: ExecutionPlan | None = None,
        proj: proj_lib.Projection | None = None,
    ) -> "ActiveSearcher":
        """Build the paper's grid image + CSR buckets and wrap them in a
        handle.  proj defaults to a PCA projection to the grid plane."""
        cfg = cfg or GridConfig()
        if proj is None:
            proj = proj_lib.pca_projection(points, grid_dim=2)
        index = build_index(points, cfg, proj, labels=labels, ids=ids)
        return cls(index=index, cfg=cfg, plan=plan or ExecutionPlan())

    @classmethod
    def from_index(
        cls,
        index: GridIndex,
        cfg: GridConfig,
        plan: ExecutionPlan | None = None,
    ) -> "ActiveSearcher":
        """Wrap an already-built GridIndex (e.g. a kNN-LM datastore).

        Pre-layout indexes (pyr_tiles=None, e.g. restored from an old
        checkpoint or assembled by hand) are upgraded HERE, exactly once:
        the pallas count path refuses to re-flatten the pyramid per call.
        """
        if cfg.counter == "pyramid" and index.pyr_tiles is None:
            index = index._replace(
                pyr_tiles=flatten_pyramid_tiles(index.pyramid, cfg.tile)
            )
        return cls(index=index, cfg=cfg, plan=plan or ExecutionPlan())

    @classmethod
    def build_sharded(
        cls,
        points: jax.Array,
        *,
        mesh: Any,
        axis: str,
        labels: jax.Array | None = None,
        ids: jax.Array | None = None,
        cfg: GridConfig | None = None,
        plan: ExecutionPlan | None = None,
        proj: proj_lib.Projection | None = None,
    ) -> "ActiveSearcher":
        """One grid per mesh shard with GLOBAL point ids; searches merge the
        per-shard top-k lists (backend "sharded", core/distributed.py)."""
        from repro.core import distributed as dist

        cfg = cfg or GridConfig()
        if proj is None:
            proj = proj_lib.pca_projection(points, grid_dim=2)
        index = dist.build_sharded_index(
            points, cfg, proj, mesh, axis, labels, ids=ids)
        plan = dataclasses.replace(plan or ExecutionPlan(), backend="sharded")
        return cls(index=index, cfg=cfg, plan=plan, mesh=mesh, axis=axis)

    def with_plan(
        self, plan: ExecutionPlan | None = None, **overrides
    ) -> "ActiveSearcher":
        """Same index, new execution plan (full plan or field overrides).

        Switching `backend=` drops the backend-specific `interpret` and
        `d_chunk` knobs when the new backend does not support them (unless
        explicitly overridden too), so
        `pallas_plan_handle.with_plan(backend="exact")` works instead of
        tripping the capability validation."""
        if plan is not None and overrides:
            raise ValueError("pass a full ExecutionPlan OR field overrides")
        if plan is None and "backend" in overrides:
            impl = _REGISTRY.get(overrides["backend"])
            if impl is not None:
                if not impl.supports_interpret and "interpret" not in overrides:
                    overrides = {**overrides, "interpret": None}
                if not impl.supports_d_chunk and "d_chunk" not in overrides:
                    overrides = {**overrides, "d_chunk": None}
                if (not impl.supports_adaptive_r0
                        and "adaptive_r0" not in overrides):
                    overrides = {**overrides, "adaptive_r0": False}
                if (not impl.supports_quantized
                        and "rerank_k" not in overrides):
                    overrides = {**overrides, "rerank_k": None}
        new = plan if plan is not None else dataclasses.replace(self.plan, **overrides)
        return dataclasses.replace(self, plan=new)

    # ------------------------------------------------------------- mutation --
    def _check_mutation(self) -> None:
        """Eager capability validation: the plan's backend must be able to
        serve the refreshed snapshot a mutation produces."""
        impl = get_backend(self.plan.backend)
        if not impl.supports_mutation:
            mutable_backends = [
                n for n in registered_backends()
                if get_backend(n).supports_mutation
            ]
            raise ValueError(
                f"backend {self.plan.backend!r} does not support mutation "
                f"(BackendImpl.supports_mutation); insert/delete need one "
                f"of {mutable_backends}"
            )

    def _mutable_state(self):
        """Current mutation state, opening the index on first use (per-shard
        MutableIndex states for sharded handles, one state for dense)."""
        from repro.core import mutable as mut

        if self.mutable is not None:
            return self.mutable
        if self.mesh is not None:
            from repro.core import distributed as dist

            return dist.open_sharded(self.index, self.cfg)
        return mut.from_index(self.index, self.cfg)

    def _carry_mutation_stats(self, new, compactions: int, compact_s: float):
        """Accumulate dense-path compaction accounting on the NEW handle
        (same __dict__ side-channel as the exact-order memo; sharded handles
        carry theirs inside ShardedMutable instead)."""
        prev = self.__dict__.get(
            "_mutation_stats", {"compactions": 0, "compact_s": 0.0}
        )
        object.__setattr__(new, "_mutation_stats", {
            "compactions": prev["compactions"] + compactions,
            "compact_s": prev["compact_s"] + compact_s,
        })
        return new

    def insert(
        self,
        points: jax.Array,
        *,
        labels: jax.Array | None = None,
        ids: jax.Array | None = None,
    ) -> "ActiveSearcher":
        """Streaming insert: delta-update the grid, pyramid, and dirty tiles
        (core/mutable.py) and return a NEW handle over the grown index.

        This handle is unchanged (handles are immutable); the returned one
        carries the refreshed dense snapshot plus the slack state, so chained
        inserts keep reusing free bucket slots.  Being a new object, it also
        starts with a cold memoized exact-order cache — the `exact` backend
        re-derives its original-order view over the grown contents instead of
        serving stale memoized arrays.  Results are bit-identical to
        rebuilding from the union of the points (tests/test_mutable.py).

        Sharded handles route every point to its owning shard (grid-cell
        ownership, core/distributed.py) and delta-insert per shard; the same
        insert == rebuild bit-parity holds on the "sharded" backend
        (tests/test_sharded_mutable.py).
        """
        from repro.core import mutable as mut

        self._check_mutation()
        state = self._mutable_state()
        if self.mesh is not None:
            from repro.core import distributed as dist

            state = dist.sharded_insert(state, self.cfg, points,
                                        labels=labels, ids=ids)
            index = dist.stacked_snapshot(state, self.cfg, self.mesh,
                                          self.axis)
            return dataclasses.replace(self, index=index, mutable=state)
        state, report = mut.insert_tracked(state, self.cfg, points,
                                           labels=labels, ids=ids)
        new = dataclasses.replace(
            self, index=mut.snapshot(state, self.cfg), mutable=state
        )
        return self._carry_mutation_stats(
            new, report.compactions, report.compact_s
        )

    def delete(self, ids: jax.Array) -> "ActiveSearcher":
        """Delete by global point id; returns a NEW handle (see `insert`).
        On sharded handles the ids are matched globally (strict accounting
        across shards) and tombstoned on whichever shards carry them."""
        from repro.core import mutable as mut

        self._check_mutation()
        state = self._mutable_state()
        if self.mesh is not None:
            from repro.core import distributed as dist

            state = dist.sharded_delete(state, self.cfg, ids)
            index = dist.stacked_snapshot(state, self.cfg, self.mesh,
                                          self.axis)
            return dataclasses.replace(self, index=index, mutable=state)
        state = mut.delete(state, self.cfg, ids)
        new = dataclasses.replace(
            self, index=mut.snapshot(state, self.cfg), mutable=state
        )
        return self._carry_mutation_stats(new, 0, 0.0)

    def snapshot(self) -> "ActiveSearcher":
        """A frozen handle over the current contents.

        Drops the slack state: later insert/delete on either handle cannot
        affect the other (delta updates build NEW arrays — jax arrays are
        immutable — so a snapshot taken mid-serving stays valid while the
        source keeps mutating).

        On a SHARDED handle this also merges the per-shard stores into ONE
        dense handle (plan switched to the "jnp" backend, mesh dropped)
        whose index is bit-identical to an unsharded `build_index` over the
        same points — cells are wholly shard-owned, so the merge reproduces
        the global CSR order exactly (distributed.merge_to_dense)."""
        if self.mesh is None:
            return dataclasses.replace(self, mutable=None)
        from repro.core import distributed as dist

        dense = dist.merge_to_dense(self.index, self.cfg)
        out = self.with_plan(backend="jnp")
        return dataclasses.replace(
            out, index=dense, mesh=None, axis=None, mutable=None
        )

    # ------------------------------------------------------------- dispatch --
    def _impl(self, op: str) -> Callable:
        """Resolve the plan's backend and validate the plan EAGERLY (before
        any tracing), so every backend raises the same errors for the same
        misuses."""
        impl = get_backend(self.plan.backend)
        if self.plan.interpret is not None and not impl.supports_interpret:
            raise ValueError(
                f"interpret= only applies to Pallas-backed backends; "
                f"backend {self.plan.backend!r} does not support it"
            )
        if self.plan.d_chunk is not None and not impl.supports_d_chunk:
            raise ValueError(
                f"d_chunk= only applies to Pallas candidate-ranking "
                f"backends; backend {self.plan.backend!r} does not "
                f"support it"
            )
        if self.plan.adaptive_r0 and not impl.supports_adaptive_r0:
            raise ValueError(
                f"adaptive_r0= only applies to backends that run the Eq.-1 "
                f"radius loop; backend {self.plan.backend!r} does not "
                f"support it"
            )
        if self.plan.rerank_k is not None and not impl.supports_quantized:
            raise ValueError(
                f"rerank_k= only applies to quantized-candidate backends "
                f"(BackendImpl.supports_quantized); backend "
                f"{self.plan.backend!r} does not support it"
            )
        fn = getattr(impl, op)
        if fn is None:
            raise ValueError(
                f"backend {self.plan.backend!r} does not implement {op}()"
            )
        return fn

    def _place(self, arr: jax.Array) -> jax.Array:
        if self.plan.device is None:
            return arr
        return jax.device_put(arr, self.plan.device, donate=self.plan.donate)

    @staticmethod
    def _check_mode(mode: str) -> None:
        if mode not in _MODES:
            raise ValueError(f"unknown mode {mode!r}; expected one of {_MODES}")

    # ------------------------------------------------------------------ ops --
    def search(self, queries: jax.Array, k: int, mode: str = "refined") -> SearchResult:
        """Batched active search: queries (B, d) -> SearchResult, leading B.

        mode="paper":   members of the final Eq.-1 circle, ranked by
                        grid-pixel distance.
        mode="refined": candidates re-ranked by the true metric in the
                        original space (recommended).
        """
        self._check_mode(mode)
        fn = self._impl("search")
        q = self._place(jnp.asarray(queries))
        return run_chunked(lambda c: fn(self, c, k, mode), q, self.plan.chunk_size)

    def classify(self, queries: jax.Array, k: int, mode: str = "refined") -> jax.Array:
        """kNN classification: (B, d) -> (B,) int32 class predictions."""
        self._check_mode(mode)
        if self.cfg.n_classes <= 0:
            raise ValueError("classify() needs an index built with n_classes > 0")
        fn = self._impl("classify")
        q = self._place(jnp.asarray(queries))
        return run_chunked(lambda c: fn(self, c, k, mode), q, self.plan.chunk_size)

    def count_at(self, queries: jax.Array, radii: jax.Array) -> jax.Array:
        """Per-class circle counts (B, C) at the given radii (pixels) — the
        paper's count primitive, exposed for diagnostics and benchmarks.
        queries are ORIGINAL-space (B, d); projection happens here.
        plan.chunk_size streams (q_grid, radius) pairs like search does."""
        fn = self._impl("count_at")
        q = self._place(jnp.asarray(queries))
        q_grid = proj_lib.to_grid_coords(self.index.proj, q, self.cfg.grid_size)
        return run_chunked(
            lambda qr: fn(self, qr[0], qr[1]),
            (q_grid, jnp.asarray(radii, jnp.int32)),
            self.plan.chunk_size,
        )

    def stats(self) -> dict[str, Any]:
        """Static facts about the handle: index shape/memory + plan."""
        idx, cfg = self.index, self.cfg
        tile_bytes = (
            0 if idx.pyr_tiles is None
            else idx.pyr_tiles.size * idx.pyr_tiles.dtype.itemsize
        )
        pyramid_bytes = sum(a.size * a.dtype.itemsize for a in idx.pyramid)
        csr_bytes = sum(
            a.size * a.dtype.itemsize
            for a in (idx.points_sorted, idx.coords_sorted,
                      idx.labels_sorted, idx.ids_sorted, idx.offsets)
        )
        if self.mutable is None:
            mutation_stats = {}
        elif self.mesh is not None:
            from repro.core import distributed as dist

            mutation_stats = dist.sharded_stats(self.mutable)
        else:
            mutation_stats = {
                "free_bucket_slots": int(self.mutable.free_bucket_slots),
                "spill_used": int(self.mutable.spill_used),
                "spill_capacity": self.mutable.spill_capacity,
                **self.__dict__.get(
                    "_mutation_stats", {"compactions": 0, "compact_s": 0.0}
                ),
            }
        return {
            # LIVE record count from the CSR offsets: dense handles end at
            # offsets[-1] == N, sharded handles sum per-shard live prefixes
            # — the stacked layout's pow2 pad rows must NOT count
            "n_points": int(jnp.sum(idx.offsets[..., -1])),
            "dim": int(idx.points_sorted.shape[-1]),
            "grid_size": cfg.grid_size,
            "padded_size": cfg.padded_size,
            "levels": cfg.levels,
            "n_classes": cfg.n_classes,
            "metric": cfg.metric,
            "counter": cfg.counter,
            "backend": self.plan.backend,
            "plan": self.plan,
            "sharded": self.mesh is not None,
            "pyramid_bytes": int(pyramid_bytes),
            "pyr_tiles_bytes": int(tile_bytes),
            "csr_bytes": int(csr_bytes),
            "mutable": self.mutable is not None,
            **mutation_stats,
        }


# ------------------------------------------------------ built-in backends ----


def _jnp_search(s: ActiveSearcher, queries, k, mode):
    return _search_jnp(s.index, s.cfg, queries, k, mode,
                       adaptive_r0=s.plan.adaptive_r0)


def _jnp_classify(s: ActiveSearcher, queries, k, mode):
    from repro.core.active_search import _classify_jnp

    return _classify_jnp(s.index, s.cfg, queries, k, mode,
                         adaptive_r0=s.plan.adaptive_r0)


def _jnp_count_at(s: ActiveSearcher, q_grid, radii):
    return _count_jnp(s.index, s.cfg, q_grid, radii)


@partial(jax.jit, static_argnames=("cfg",))
def _count_jnp(index: GridIndex, cfg: GridConfig, q_grid, radii):
    return jax.vmap(lambda g, r: pyr.count_in_circle(index, cfg, g, r))(
        q_grid, radii
    )


def _pallas_search(s: ActiveSearcher, queries, k, mode, pipeline="fused"):
    from repro.core import batched

    return batched.search(
        s.index, s.cfg, queries, k, mode=mode, interpret=s.plan.interpret,
        pipeline=pipeline, d_chunk=s.plan.d_chunk,
        adaptive_r0=s.plan.adaptive_r0,
    )


def _pallas_classify(s: ActiveSearcher, queries, k, mode, pipeline="fused"):
    from repro.core import batched

    return batched.classify(
        s.index, s.cfg, queries, k, mode=mode, interpret=s.plan.interpret,
        pipeline=pipeline, d_chunk=s.plan.d_chunk,
        adaptive_r0=s.plan.adaptive_r0,
    )


def _pallas_gather_search(s: ActiveSearcher, queries, k, mode):
    return _pallas_search(s, queries, k, mode, pipeline="gather")


def _pallas_gather_classify(s: ActiveSearcher, queries, k, mode):
    return _pallas_classify(s, queries, k, mode, pipeline="gather")


def _quantized_store(s: ActiveSearcher):
    """The handle's int8 candidate store (core/quantized.py), memoized.

    Same __dict__ side-channel as `_exact_ordered`: frozen dataclasses
    still allow attribute caching, the quantization runs once per handle,
    and every mutation (insert/delete/snapshot) returns a NEW handle, so
    the memo can never serve a store for stale contents.  Never cached
    under a trace (tracers on the handle would leak into later calls)."""
    from repro.core import quantized as qz

    cached = s.__dict__.get("_quantized_store_cache")
    if cached is not None:
        return cached
    store = qz.quantize_index(s.index, s.cfg)
    if not any(isinstance(a, jax.core.Tracer) for a in store):
        object.__setattr__(s, "_quantized_store_cache", store)
    return store


def _pallas_q8_search(s: ActiveSearcher, queries, k, mode):
    from repro.core import batched

    return batched.search_q8(
        s.index, _quantized_store(s), s.cfg, queries, k, mode=mode,
        rerank_k=s.plan.rerank_k, interpret=s.plan.interpret,
        d_chunk=s.plan.d_chunk, adaptive_r0=s.plan.adaptive_r0,
    )


def _pallas_q8_classify(s: ActiveSearcher, queries, k, mode):
    from repro.core import batched

    return batched.classify_q8(
        s.index, _quantized_store(s), s.cfg, queries, k, mode=mode,
        rerank_k=s.plan.rerank_k, interpret=s.plan.interpret,
        d_chunk=s.plan.d_chunk, adaptive_r0=s.plan.adaptive_r0,
    )


def _pallas_count_at(s: ActiveSearcher, q_grid, radii):
    from repro.core import batched

    return batched.batched_counts(s.index, s.cfg, q_grid, radii, s.plan.interpret)


def _pallas_stacked_count_at(s: ActiveSearcher, q_grid, radii):
    from repro.core import batched

    return batched.batched_counts_stacked(
        s.index, s.cfg, q_grid, radii, s.plan.interpret
    )


def _exact_ordered(s: ActiveSearcher):
    """CSR arrays restored to original-id order, so the exact comparator sees
    the datastore exactly as the caller supplied it (bit-identical tie
    breaks vs pre-facade `exact.knn(points, ...)` calls).

    Memoized on the handle (frozen dataclasses still allow __dict__
    caching): the O(N log N) argsort + O(N d) gathers run once per handle,
    not once per call/chunk.  NEVER cached under a trace — inside
    jit/eval_shape the reorder produces tracers, and storing those on the
    handle would leak them into later calls (UnexpectedTracerError)."""
    cached = s.__dict__.get("_exact_ordered_cache")
    if cached is not None:
        return cached
    index = s.index
    order = jnp.argsort(index.ids_sorted)
    out = (
        index.points_sorted[order],
        index.labels_sorted[order],
        index.ids_sorted[order],
    )
    if not any(isinstance(a, jax.core.Tracer) for a in out):
        object.__setattr__(s, "_exact_ordered_cache", out)
    return out


def _exact_search(s: ActiveSearcher, queries, k, mode):
    """Brute-force comparator folded into the uniform SearchResult: the
    paper-stat fields (radius/count/iters/converged/truncated) are defaulted
    since exact kNN has no Eq.-1 loop.  `mode` is accepted for interface
    uniformity; exact distances are always original-space."""
    pts, labels, ids = _exact_ordered(s)
    res = exact_lib.knn(
        jnp.asarray(queries, jnp.float32), pts, k, metric=s.cfg.metric
    )
    b = res.ids.shape[0]
    valid = jnp.isfinite(res.dists) & (res.ids >= 0)
    pos = jnp.clip(res.ids, 0, pts.shape[0] - 1)
    return SearchResult(
        ids=jnp.where(valid, ids[pos], -1),
        dists=jnp.where(valid, res.dists, jnp.inf).astype(jnp.float32),
        labels=jnp.where(valid, labels[pos], -1),
        valid=valid,
        radius=jnp.zeros((b,), jnp.int32),
        count=jnp.sum(valid, axis=1).astype(jnp.int32),
        iters=jnp.zeros((b,), jnp.int32),
        converged=jnp.ones((b,), bool),
        truncated=jnp.zeros((b,), bool),
    )


def _exact_classify(s: ActiveSearcher, queries, k, mode):
    pts, labels, _ = _exact_ordered(s)
    return exact_lib.classify(
        jnp.asarray(queries, jnp.float32), pts, labels, k,
        s.cfg.n_classes, metric=s.cfg.metric,
    )


def _sharded_search(s: ActiveSearcher, queries, k, mode):
    if s.mesh is None or s.axis is None:
        raise ValueError(
            "backend 'sharded' needs a handle from ActiveSearcher."
            "build_sharded (mesh + axis)"
        )
    from repro.core import distributed as dist

    return dist.sharded_search(
        s.index, s.cfg, queries, k, s.mesh, s.axis, mode=mode,
        adaptive_r0=s.plan.adaptive_r0,
    )


def _sharded_classify(s: ActiveSearcher, queries, k, mode):
    """Majority vote over the globally merged top-k.

    Unlike the single-index jnp/pallas paths there is NO count-based
    fallback for short/truncated lanes: Eq. 1 converges to a DIFFERENT
    radius on every shard, so "per-class counts at the final radius" has no
    global definition.  mode="paper" (pure count argmax) is rejected for
    the same reason."""
    if mode != "refined":
        raise ValueError("backend 'sharded' classifies in mode='refined' only")
    from repro.core.active_search import majority_vote

    res = _sharded_search(s, queries, k, "refined")
    return majority_vote(res.labels, res.valid, s.cfg.n_classes)


register_backend("jnp", BackendImpl(
    search=_jnp_search, classify=_jnp_classify, count_at=_jnp_count_at,
    supports_adaptive_r0=True, supports_mutation=True,
    description="per-query reference pipeline under jax.vmap (pure lax/jnp)",
))
register_backend("pallas", BackendImpl(
    search=_pallas_search, classify=_pallas_classify,
    count_at=_pallas_count_at, supports_interpret=True,
    supports_d_chunk=True, supports_adaptive_r0=True,
    supports_mutation=True,
    description="batched kernel pipeline: level-scheduled "
                "tile_count_multilevel + FUSED csr_candidate_topk (candidate "
                "rows DMA'd straight from the CSR store; no (B, w*row_cap) "
                "intermediate) (core/batched.py)",
))
register_backend("pallas_gather", BackendImpl(
    search=_pallas_gather_search, classify=_pallas_gather_classify,
    count_at=_pallas_count_at, supports_interpret=True,
    supports_d_chunk=True, supports_adaptive_r0=True,
    supports_mutation=True,
    description="benchmark baseline / second oracle: same counting, but the "
                "candidate stage is the PR-1..4 one-shot (B, w*row_cap) "
                "four-field gather + dense candidate_topk",
))
register_backend("pallas_q8", BackendImpl(
    search=_pallas_q8_search, classify=_pallas_q8_classify,
    count_at=_pallas_count_at, supports_interpret=True,
    supports_d_chunk=True, supports_adaptive_r0=True,
    supports_mutation=True, supports_quantized=True,
    description="quantized candidate stage: int8 store DMA + int32 VPU "
                "scoring shortlists top-rerank_k rows, then an exact fp32 "
                "re-rank of the shortlist emits the final (dists, ids).  "
                "Recall contract vs the exact backends (approximate in "
                "WHICH rows shortlist, never in returned distances); "
                "counting stage identical to 'pallas' "
                "(core/quantized.py + core/batched.py)",
))
register_backend("pallas_stacked", BackendImpl(
    count_at=_pallas_stacked_count_at, supports_interpret=True,
    description="count-only benchmark baseline: the PR-1 per-level "
                "tile_count stack + select",
))
register_backend("exact", BackendImpl(
    search=_exact_search, classify=_exact_classify, supports_mutation=True,
    description="blocked brute-force kNN — the paper's 'original kNN' "
                "comparator (core/exact.py)",
))
register_backend("sharded", BackendImpl(
    search=_sharded_search, classify=_sharded_classify, requires_mesh=True,
    supports_adaptive_r0=True, supports_mutation=True,
    description="per-shard searchers under shard_map + (dist, global id) "
                "lexicographic top-k merge; mutation routed by grid-cell "
                "ownership (core/distributed.py; build via build_sharded)",
))


__all__ = [
    "ActiveSearcher",
    "BackendImpl",
    "ExecutionPlan",
    "SearchResult",
    "get_backend",
    "register_backend",
    "registered_backends",
]
