"""Paper §3 accuracy experiment, faithful settings: 3000x3000 image, r0=100,
k=11, 3 classes, 100 query points, exact kNN as ground truth.  The paper
reports 'up to 98%'."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, paper_data
from repro.api import ActiveSearcher, identity_projection
from repro.configs.paper_active_search import K, N_CLASSES, N_QUERIES, PAPER_GRID


def main(ns=(1_000, 10_000, 100_000), seeds=(0, 1, 2)) -> None:
    csv = Csv("n,seed,mode,accuracy_vs_exact")
    for n in ns:
        for seed in seeds:
            rng = np.random.default_rng(seed)
            pts, labels = paper_data(rng, n, N_CLASSES)
            searcher = ActiveSearcher.build(
                pts, labels=labels, cfg=PAPER_GRID,
                proj=identity_projection(pts),
            )
            q, _ = paper_data(rng, N_QUERIES)
            truth = searcher.with_plan(backend="exact").classify(q, K)
            for mode in ("paper", "refined"):
                pred = searcher.classify(q, K, mode=mode)
                acc = float(np.mean(np.asarray(pred) == np.asarray(truth)))
                csv.row(n, seed, mode, f"{acc:.3f}")
    return csv


if __name__ == "__main__":
    main()
