"""Mutable grid index — streaming insert/delete as DELTA updates.

`build_index` produces a frozen snapshot: CSR buckets packed edge to edge,
pyramid summed from scratch, tiles flattened once.  Serving workloads (the
kNN-LM datastore growing during decode, retrieval positions appended token by
token) need the index to GROW without paying the O(N log N) rebuild, so this
module keeps the same structure in a mutable layout:

  * the CSR record arrays get per-cell SLACK — each bucket is allocated
    `capacity >= size` slots, so an insert into a bucket with free slots is
    one scatter per record field;
  * inserts that do not fit their bucket (full bucket, or a cell that was
    empty at layout time) go to a SPILL log, an append-only slab merged back
    into cell order by `snapshot()`/`compact()` with an O(N) order-preserving
    merge (no full argsort);
  * deletes tombstone their slot (`live=False`) — bucket order is preserved,
    the slot is reclaimed at the next `compact()`;
  * the count pyramid is maintained exactly by scatter-adding +/-1 at every
    level for each touched cell (integer adds, so the result is bit-identical
    to a from-scratch `build_pyramid`), and only the DIRTY T-tiles of the
    flattened `pyr_tiles` layout are re-gathered;
  * when the spill log itself overflows, `insert` takes the escape hatch:
    `compact()` (re-layout with fresh slack; order-preserving, no sort) by
    default, or raises `BucketOverflow` with `on_overflow="raise"`.

The headline invariant (tests/test_mutable.py): for any split P = P1 ∪ P2,

    snapshot(insert(from_index(build_index(P1)), P2)) == build_index(P)

bit for bit — same CSR order (stable argsort puts same-cell points in
arrival order; buckets + spill reproduce exactly that), same offsets, same
pyramid, same flattened tiles — so every registered search backend returns
identical results on the incrementally built index.

Facade surface: `ActiveSearcher.insert/.delete/.snapshot()` (core/engine.py)
carry a `MutableIndex` alongside the dense snapshot; `retrieval_memory` and
`knn_lm` expose `extend_*` helpers on top of it; `checkpoint/store.py`
persists the state via `state_to_tree`/`state_from_tree`.
"""

from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import projection as proj_lib
from repro.core.grid import (
    GridConfig,
    GridIndex,
    build_index,
    cell_id_of,
    flatten_pyramid_tiles,
)
from repro.core.projection import Projection


class BucketOverflow(RuntimeError):
    """An insert did not fit the bucket slack and the spill log is full.

    Raised only with `on_overflow="raise"`; the default policy compacts the
    layout (fresh slack, spill merged back into buckets) and retries.
    """


class Slab(NamedTuple):
    """One block of CSR slot storage (the bucketed base, or the spill log).

    Dead/free slots carry `ids == -1`, `cell == -1`, `live == False`.
    """

    points: jax.Array  # (cap, d) float32
    coords: jax.Array  # (cap, 2) float32
    labels: jax.Array  # (cap,) int32
    ids: jax.Array     # (cap,) int32
    cell: jax.Array    # (cap,) int32 — flat base cell id of the slot's record
    live: jax.Array    # (cap,) bool


class MutableIndex(NamedTuple):
    """A grid index open for streaming mutation.  All-array pytree.

    `base` holds the bucketed records: bucket c occupies slots
    [cap_offsets[c], cap_offsets[c+1]); the first `used[c]` slots of the
    bucket have been handed out (some may be tombstoned), the rest are free.
    `spill` is the append-only overflow log in ARRIVAL order; `spilled[c]`
    pins a cell to the spill log once any of its inserts spilled, so bucket
    slots never receive records that must sort AFTER spilled ones.
    """

    proj: Projection
    base: Slab
    spill: Slab
    cap_offsets: jax.Array  # (G*G + 1,) int32 bucket capacity prefix sum
    used: jax.Array         # (G*G,) int32 slots handed out per bucket
    spilled: jax.Array      # (G*G,) bool — cell routes to the spill log
    spill_used: jax.Array   # () int32 — occupied prefix of the spill slab
    pyramid: tuple[jax.Array, ...]
    pyr_tiles: jax.Array | None
    next_id: jax.Array      # () int32 — next auto-assigned global id
    n_live: jax.Array       # () int32 — live records (base + spill)

    @property
    def spill_capacity(self) -> int:
        return self.spill.ids.shape[0]

    @property
    def free_bucket_slots(self) -> jax.Array:
        """() int32 — total unallocated bucket slots across all cells."""
        caps = self.cap_offsets[1:] - self.cap_offsets[:-1]
        return jnp.sum(caps - self.used)


# ------------------------------------------------------------ construction ---


def _empty_slab(cap: int, d: int) -> Slab:
    return Slab(
        points=jnp.zeros((cap, d), jnp.float32),
        coords=jnp.zeros((cap, 2), jnp.float32),
        labels=jnp.zeros((cap,), jnp.int32),
        ids=jnp.full((cap,), -1, jnp.int32),
        cell=jnp.full((cap,), -1, jnp.int32),
        live=jnp.zeros((cap,), bool),
    )


def _scatter_slab(slab: Slab, pos: jax.Array, keep: jax.Array, *,
                  points, coords, labels, ids, cell) -> Slab:
    """Write records into `slab` at `pos` where `keep`; dropped elsewhere."""
    cap = slab.ids.shape[0]
    idx = jnp.where(keep, pos, cap)  # out-of-range rows drop
    return Slab(
        points=slab.points.at[idx].set(points, mode="drop"),
        coords=slab.coords.at[idx].set(coords, mode="drop"),
        labels=slab.labels.at[idx].set(labels, mode="drop"),
        ids=slab.ids.at[idx].set(ids, mode="drop"),
        cell=slab.cell.at[idx].set(cell, mode="drop"),
        live=slab.live.at[idx].set(True, mode="drop"),
    )


@partial(jax.jit, static_argnames=("g", "total_cap", "d"))
def _layout_base(index: GridIndex, cap_offsets, g: int, total_cap: int, d: int):
    n = index.points_sorted.shape[0]
    cell = cell_id_of(index.coords_sorted, g)                       # (N,)
    # CSR rank within the cell -> bucket slot
    pos = cap_offsets[cell] + (jnp.arange(n, dtype=jnp.int32) - index.offsets[cell])
    return _scatter_slab(
        _empty_slab(total_cap, d), pos, jnp.ones((n,), bool),
        points=index.points_sorted, coords=index.coords_sorted,
        labels=index.labels_sorted, ids=index.ids_sorted, cell=cell,
    )


def from_index(
    index: GridIndex,
    cfg: GridConfig,
    slack: float = 0.5,
    min_slack: int = 4,
    spill_capacity: int | None = None,
    next_id: int | None = None,
) -> MutableIndex:
    """Open a built `GridIndex` for mutation.

    Bucket capacity is `size + max(ceil(slack * size), min_slack)` for
    non-empty cells (empty cells get no slots — their inserts spill).  The
    layout pass is O(N) scatters; no sort.
    """
    g = cfg.padded_size
    n = index.n_points
    d = index.points_sorted.shape[1]

    sizes = index.offsets[1:] - index.offsets[:-1]                  # (G*G,)
    extra = jnp.maximum(
        jnp.ceil(sizes.astype(jnp.float32) * slack).astype(jnp.int32),
        jnp.int32(min_slack),
    )
    caps = jnp.where(sizes > 0, sizes + extra, 0)
    cap_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(caps).astype(jnp.int32)]
    )
    total_cap = int(cap_offsets[-1])
    base = _layout_base(index, cap_offsets, g, total_cap, d)

    if spill_capacity is None:
        spill_capacity = max(1024, n // 4)
    tiles = index.pyr_tiles
    if tiles is None and cfg.counter == "pyramid":
        tiles = flatten_pyramid_tiles(index.pyramid, cfg.tile)
    if next_id is None:
        next_id = int(index.ids_sorted.max()) + 1 if n else 0
    return MutableIndex(
        proj=index.proj,
        base=base,
        spill=_empty_slab(spill_capacity, d),
        cap_offsets=cap_offsets,
        used=sizes,
        spilled=jnp.zeros((g * g,), bool),
        spill_used=jnp.int32(0),
        pyramid=index.pyramid,
        pyr_tiles=tiles,
        next_id=jnp.int32(next_id),
        n_live=jnp.int32(n),
    )


# ------------------------------------------------------------ delta helpers --


def _pyramid_delta(
    pyramid: tuple[jax.Array, ...], cx, cy, chan, amount
) -> tuple[jax.Array, ...]:
    """Scatter `amount` per (cell, channel) into EVERY level (exact int
    adds; amount may be a per-entry array, so padding entries can add 0)."""
    out = []
    for lv, arr in enumerate(pyramid):
        out.append(arr.at[cx >> lv, cy >> lv, chan].add(amount))
    return tuple(out)


def _pad_pow2(arr: np.ndarray, fill) -> np.ndarray:
    """Pad a 1-D host array to the next power-of-two length (bounds the
    number of distinct shapes the jitted delta kernels compile for)."""
    n = len(arr)
    cap = 1 << max(n - 1, 0).bit_length()
    return np.concatenate([arr, np.full((cap - n,), fill, arr.dtype)])


def _dirty_tile_rows(cfg: GridConfig, cx, cy) -> list[np.ndarray]:
    """Per level, the UNIQUE flat `pyr_tiles` rows covering the given cells."""
    t = cfg.tile
    rows = []
    for lv, nblk in enumerate(cfg.level_nblks):
        bx = np.asarray(cx >> lv) // t
        by = np.asarray(cy >> lv) // t
        rows.append(np.unique(bx * nblk + by).astype(np.int32))
    return rows


@partial(jax.jit, static_argnames=("t", "nblk", "offset"))
def _update_tiles_level(pyr_tiles, level_arr, local, t: int, nblk: int, offset: int):
    """Re-gather the given flat tile rows of ONE level from its (already
    delta-updated) image.  `local` may contain duplicates (pow2 padding
    repeats a row); duplicate rows re-write identical fresh content."""
    bx, by = local // nblk, local % nblk
    fresh = jax.vmap(
        lambda x, y: jax.lax.dynamic_slice(
            level_arr, (x * t, y * t, 0), (t, t, level_arr.shape[-1])
        )
    )(bx, by)
    return pyr_tiles.at[local + offset].set(fresh, unique_indices=False)


_flatten_tiles_jit = jax.jit(flatten_pyramid_tiles, static_argnames=("tile",))


def _refresh_tiles(
    pyr_tiles: jax.Array | None,
    pyramid: tuple[jax.Array, ...],
    cfg: GridConfig,
    cx,
    cy,
) -> jax.Array | None:
    """Re-flatten ONLY the T-tiles whose counts changed.

    Each dirty row is re-gathered from its (already delta-updated) pyramid
    level with one dynamic_slice — O(dirty * T^2) instead of O(sum_l S_l^2).
    Falls back to a full `flatten_pyramid_tiles` when most rows are dirty.
    """
    if pyr_tiles is None:
        return None
    t = cfg.tile
    per_level = _dirty_tile_rows(cfg, cx, cy)
    n_dirty = sum(len(r) for r in per_level)
    if n_dirty * 4 >= pyr_tiles.shape[0]:
        return _flatten_tiles_jit(pyramid, tile=t)

    offset = 0
    for lv, nblk in enumerate(cfg.level_nblks):
        local = per_level[lv]
        if len(local):
            # pad by repeating the first dirty row: idempotent re-write
            padded = jnp.asarray(_pad_pow2(local, local[0]))
            pyr_tiles = _update_tiles_level(
                pyr_tiles, pyramid[lv], padded, t, nblk, offset
            )
        offset += nblk * nblk
    return pyr_tiles


def _chan_of(labels: jax.Array, cfg: GridConfig) -> jax.Array:
    return jnp.where(cfg.n_classes > 0, labels, 0).astype(jnp.int32)


# ----------------------------------------------------------------- insert ----


@partial(jax.jit, static_argnames=("cfg",))
def _plan_insert(m: MutableIndex, cfg: GridConfig, points, n_real):
    """coords/cell/arrival-rank/fits for a (pow2-padded) insert batch.

    Rows past `n_real` are padding: they get the sentinel cell G*G so they
    cannot perturb the arrival ranks of real cells, and `fits` is False for
    them (every downstream scatter drops on the keep/fits masks)."""
    g = cfg.padded_size
    mn = points.shape[0]
    keep = jnp.arange(mn, dtype=jnp.int32) < n_real
    coords = proj_lib.to_grid_coords(m.proj, points, cfg.grid_size)
    cid = jnp.where(keep, cell_id_of(coords, g), g * g)

    # arrival rank within each cell of THIS batch (stable sort by cell)
    order = jnp.argsort(cid, stable=True)
    sorted_cid = cid[order]
    rank_sorted = jnp.arange(mn, dtype=jnp.int32) - jnp.searchsorted(
        sorted_cid, sorted_cid, side="left"
    ).astype(jnp.int32)
    rank = jnp.zeros((mn,), jnp.int32).at[order].set(rank_sorted)

    caps = m.cap_offsets[1:] - m.cap_offsets[:-1]
    c = jnp.minimum(cid, g * g - 1)  # sentinel-safe gathers (masked by keep)
    fits = (~m.spilled[c]) & (m.used[c] + rank < caps[c]) & keep
    return coords, cid, rank, fits, keep


@partial(jax.jit, static_argnames=("cfg", "has_spill"))
def _apply_insert(
    m: MutableIndex, cfg: GridConfig, points, coords, cid, rank, fits, keep,
    labels, ids, has_spill: bool,
) -> MutableIndex:
    g = cfg.padded_size
    # sentinel rows index used[] out of bounds (gather clamps) — harmless,
    # their fits is False so the scatter drops them
    base = _scatter_slab(
        m.base, m.cap_offsets[cid] + m.used[jnp.minimum(cid, g * g - 1)] + rank,
        fits,
        points=points, coords=coords, labels=labels, ids=ids, cell=cid,
    )
    used = m.used.at[jnp.where(fits, cid, g * g)].add(1, mode="drop")

    spill, spilled, spill_used = m.spill, m.spilled, m.spill_used
    sp = (~fits) & keep
    if has_spill:
        # spill keeps ARRIVAL order: rank the non-fitting points by batch pos
        sp_rank = jnp.cumsum(sp.astype(jnp.int32)) - 1
        spill = _scatter_slab(
            spill, m.spill_used + sp_rank, sp,
            points=points, coords=coords, labels=labels, ids=ids, cell=cid,
        )
        spilled = spilled.at[jnp.where(sp, cid, g * g)].set(True, mode="drop")
        spill_used = m.spill_used + jnp.sum(sp.astype(jnp.int32))

    # padding rows land on the sentinel cell (cx == g, dropped out of
    # bounds) with amount 0 — doubly inert
    pyramid = _pyramid_delta(
        m.pyramid, cid // g, cid % g, _chan_of(labels, cfg),
        keep.astype(jnp.int32),
    )
    return m._replace(
        base=base,
        spill=spill,
        used=used,
        spilled=spilled,
        spill_used=spill_used,
        pyramid=pyramid,
        next_id=jnp.maximum(m.next_id, ids.max() + 1),
        n_live=m.n_live + jnp.sum(keep.astype(jnp.int32)),
    )


def insert(
    m: MutableIndex,
    cfg: GridConfig,
    points: jax.Array,
    labels: jax.Array | None = None,
    ids: jax.Array | None = None,
    on_overflow: str = "compact",
) -> MutableIndex:
    """Insert a batch of points; returns a NEW state (m is unchanged).

    Each point lands in its bucket's next free slot when one exists (and the
    cell has never spilled); otherwise it appends to the spill log.  The
    pyramid and dirty tiles are delta-updated either way, so counts are
    always current — only `snapshot()` pays the (sort-free) merge.

    on_overflow: "compact" re-layouts with fresh slack and retries when the
    spill log is full; "raise" raises `BucketOverflow` instead.

    Caller-supplied `ids` should be globally unique and not collide with
    live ids — records are keyed by id, so delete(id) removes EVERY record
    carrying it.  Auto-assigned ids (ids=None) never collide.
    """
    if on_overflow not in ("compact", "raise"):
        raise ValueError(
            f"unknown on_overflow {on_overflow!r}; expected 'compact' or 'raise'"
        )
    points = jnp.asarray(points, jnp.float32)
    mn = points.shape[0]
    if mn == 0:
        return m
    if labels is None:
        labels = jnp.zeros((mn,), jnp.int32)
    labels = jnp.asarray(labels, jnp.int32)
    if ids is None:
        ids = m.next_id + jnp.arange(mn, dtype=jnp.int32)
    ids = jnp.asarray(ids, jnp.int32)

    # pow2-pad the batch (sentinel cell, keep=False, id=-1) so the jitted
    # insert kernels compile for O(log batch) distinct shapes, matching the
    # bounded-compile design of delete()
    cap = 1 << max(mn - 1, 0).bit_length()
    if cap != mn:
        pad = cap - mn
        points_p = jnp.concatenate(
            [points, jnp.broadcast_to(points[-1:], (pad,) + points.shape[1:])]
        )
        labels_p = jnp.concatenate([labels, jnp.zeros((pad,), jnp.int32)])
        ids_p = jnp.concatenate([ids, jnp.full((pad,), -1, jnp.int32)])
    else:
        points_p, labels_p, ids_p = points, labels, ids

    coords, cid, rank, fits, keep = _plan_insert(
        m, cfg, points_p, jnp.int32(mn)
    )

    n_spill = int(jnp.sum((~fits) & keep))
    if n_spill and int(m.spill_used) + n_spill > m.spill_capacity:
        if on_overflow == "raise":
            raise BucketOverflow(
                f"insert of {mn} points needs {n_spill} spill slots but only "
                f"{m.spill_capacity - int(m.spill_used)} remain; "
                f"compact() or rebuild() the index"
            )
        # compact() re-tightens bucket slack, so points that fit THIS layout
        # may spill in the fresh one — only capacity >= the whole batch
        # guarantees the retry cannot overflow the (now empty) spill log
        grow = max(2 * m.spill_capacity, mn)
        m = compact(m, cfg, spill_capacity=grow)
        return insert(m, cfg, points, labels, ids, on_overflow="raise")

    out = _apply_insert(
        m, cfg, points_p, coords, cid, rank, fits, keep, labels_p, ids_p,
        has_spill=n_spill > 0,
    )
    real_cid = cid[:mn]  # padding rows map past the last level's tile rows
    tiles = _refresh_tiles(m.pyr_tiles, out.pyramid, cfg,
                           real_cid // cfg.padded_size,
                           real_cid % cfg.padded_size)
    return out._replace(pyr_tiles=tiles)


class InsertReport(NamedTuple):
    """What `insert_tracked` did BESIDES the insert: overflow compactions and
    the wall-clock pause they cost — the serving tier's backpressure signal
    (BENCH_serve.json reports both)."""

    compactions: int
    compact_s: float


def insert_tracked(
    m: MutableIndex,
    cfg: GridConfig,
    points: jax.Array,
    labels: jax.Array | None = None,
    ids: jax.Array | None = None,
) -> tuple[MutableIndex, InsertReport]:
    """`insert` with EXPLICIT, shard-local overflow handling.

    On `BucketOverflow` this compacts THIS state only and retries — in a
    sharded tier (core/distributed.py) sibling shards keep their exact state
    objects, so one full shard never stalls the others.  The retry's spill
    capacity covers the whole batch (same rule as `insert`'s internal escape
    hatch), so it cannot overflow again.  Returns (new_state, report); the
    report carries the compaction count (0 or 1) and the blocking pause in
    seconds."""
    try:
        out = insert(m, cfg, points, labels=labels, ids=ids,
                     on_overflow="raise")
        return out, InsertReport(compactions=0, compact_s=0.0)
    except BucketOverflow:
        t0 = time.perf_counter()
        mn = int(jnp.asarray(points).shape[0])
        grow = max(2 * m.spill_capacity, mn)
        packed = compact(m, cfg, spill_capacity=grow)
        out = insert(packed, cfg, points, labels=labels, ids=ids,
                     on_overflow="raise")
        jax.block_until_ready(out.base.ids)
        return out, InsertReport(
            compactions=1, compact_s=time.perf_counter() - t0
        )


# ----------------------------------------------------------------- delete ----


def delete(
    m: MutableIndex, cfg: GridConfig, ids: jax.Array, strict: bool = True
) -> MutableIndex:
    """Tombstone the records with the given global ids; returns a NEW state.

    Bucket order is untouched (the slot just goes dead), so a later
    `snapshot()` reproduces exactly the CSR order of rebuilding from the
    surviving points.  With strict=True (default) every id must name a live
    record; strict=False ignores unknown ids.
    """
    ids = jnp.asarray(ids, jnp.int32).reshape(-1)
    if ids.shape[0] == 0:
        return m
    kill_base, kill_spill = _plan_delete(m, ids)
    n_kill = int(jnp.sum(kill_base)) + int(jnp.sum(kill_spill))

    g = cfg.padded_size
    # device-side nonzero + gathers: only O(n_kill) records cross to the
    # host (for pow2 padding), never the full slab arrays
    idx_b = jnp.nonzero(kill_base)[0]
    idx_s = jnp.nonzero(kill_spill)[0]
    dead_ids = np.asarray(
        jnp.concatenate([m.base.ids[idx_b], m.spill.ids[idx_s]])
    )
    # count matched IDS, not slots: duplicate ids (caller-supplied id
    # collisions) kill every carrier, which must not read as "id not live"
    n_asked = int(jnp.unique(ids).shape[0])
    n_matched = len(np.unique(dead_ids))
    if strict and n_matched != n_asked:
        raise KeyError(
            f"delete: {n_asked - n_matched} of {n_asked} ids are not live in "
            f"the index (already deleted, or never inserted)"
        )
    dead_cell = np.asarray(
        jnp.concatenate([m.base.cell[idx_b], m.spill.cell[idx_s]])
    ).astype(np.int32)
    dead_lab = np.asarray(
        jnp.concatenate([m.base.labels[idx_b], m.spill.labels[idx_s]])
    ).astype(np.int32)
    # pow2 padding (cell 0, amount 0) keeps the jitted delta shape-stable
    amount = _pad_pow2(np.full((n_kill,), -1, np.int32), 0)
    dead_cell = jnp.asarray(_pad_pow2(dead_cell, 0))
    dead_lab = jnp.asarray(_pad_pow2(dead_lab, 0))

    out = _apply_delete(m, cfg, kill_base, kill_spill, dead_cell, dead_lab,
                        jnp.asarray(amount), jnp.int32(n_kill))
    tiles = _refresh_tiles(m.pyr_tiles, out.pyramid, cfg,
                           dead_cell // g, dead_cell % g)
    return out._replace(pyr_tiles=tiles)


@jax.jit
def _plan_delete(m: MutableIndex, ids):
    kill_base = jnp.isin(m.base.ids, ids) & m.base.live
    in_spill = jnp.arange(m.spill.ids.shape[0]) < m.spill_used
    kill_spill = jnp.isin(m.spill.ids, ids) & m.spill.live & in_spill
    return kill_base, kill_spill


@jax.jit
def ids_live_mask(m: MutableIndex, ids: jax.Array) -> jax.Array:
    """(len(ids),) bool — which of `ids` name at least one LIVE record here.

    The sharded delete router (distributed.sharded_delete) asks every shard
    this question to do GLOBAL strict accounting before issuing per-shard
    lenient deletes.  Dead/free slots are masked to -2 (never a caller id;
    -1 is the free-slot sentinel a caller could conceivably pass)."""
    base_ids = jnp.where(m.base.live, m.base.ids, -2)
    in_spill = jnp.arange(m.spill.ids.shape[0]) < m.spill_used
    spill_ids = jnp.where(m.spill.live & in_spill, m.spill.ids, -2)
    return jnp.isin(ids, base_ids) | jnp.isin(ids, spill_ids)


@partial(jax.jit, static_argnames=("cfg",))
def _apply_delete(
    m: MutableIndex, cfg: GridConfig, kill_base, kill_spill,
    dead_cell, dead_lab, amount, n_kill,
) -> MutableIndex:
    g = cfg.padded_size
    pyramid = _pyramid_delta(
        m.pyramid, dead_cell // g, dead_cell % g,
        _chan_of(dead_lab, cfg), amount,
    )
    return m._replace(
        base=m.base._replace(live=m.base.live & ~kill_base),
        spill=m.spill._replace(live=m.spill.live & ~kill_spill),
        pyramid=pyramid,
        n_live=m.n_live - jnp.int32(n_kill),
    )


# --------------------------------------------------------------- snapshot ----


@partial(jax.jit, static_argnames=("cfg",))
def _merge_snapshot(m: MutableIndex, cfg: GridConfig):
    """The snapshot merge at FULL slab capacity (static shapes: jit caches
    one executable per layout, not per n_live); `snapshot` slices off the
    dead tail on the host."""
    g = cfg.padded_size
    n_cells = g * g
    cap_total = m.base.ids.shape[0] + m.spill.ids.shape[0]

    lb = m.base.live
    base_rank = jnp.cumsum(lb.astype(jnp.int32)) - 1                # (capB,)
    counts_b = jnp.zeros((n_cells + 1,), jnp.int32).at[
        jnp.where(lb, m.base.cell, n_cells)
    ].add(1)[:-1]
    offs_b = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts_b).astype(jnp.int32)]
    )

    in_spill = jnp.arange(m.spill.ids.shape[0]) < m.spill_used
    ls = m.spill.live & in_spill
    counts_s = jnp.zeros((n_cells + 1,), jnp.int32).at[
        jnp.where(ls, m.spill.cell, n_cells)
    ].add(1)[:-1]
    offs_s = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts_s).astype(jnp.int32)]
    )

    # dead slots sort to the end with an out-of-range key; stable argsort
    # preserves arrival order within each cell
    sp_order = jnp.argsort(
        jnp.where(ls, m.spill.cell, n_cells), stable=True
    ).astype(jnp.int32)
    sp_rank = jnp.zeros_like(sp_order).at[sp_order].set(
        jnp.arange(sp_order.shape[0], dtype=jnp.int32)
    )

    pos_b = base_rank + offs_s[jnp.clip(m.base.cell, 0, n_cells - 1)]
    pos_s = offs_b[jnp.clip(m.spill.cell, 0, n_cells - 1) + 1] + sp_rank

    # ONE int scatter builds the inverse permutation; the record fields then
    # move with plain gathers (much cheaper than 6 field scatters on CPU)
    cap_b = m.base.ids.shape[0]
    src = jnp.full((cap_total + 1,), cap_total, jnp.int32)
    src = src.at[jnp.where(lb, pos_b, cap_total)].set(
        jnp.arange(cap_b, dtype=jnp.int32), mode="drop"
    )
    src = src.at[jnp.where(ls, pos_s, cap_total)].set(
        cap_b + jnp.arange(m.spill.ids.shape[0], dtype=jnp.int32), mode="drop"
    )

    def merge(fb, fs, fill):
        pad = jnp.full((1,) + fb.shape[1:], fill, fb.dtype)
        return jnp.concatenate([fb, fs, pad])[src]

    return (
        merge(m.base.points, m.spill.points, 0.0),
        merge(m.base.coords, m.spill.coords, 0.0),
        merge(m.base.labels, m.spill.labels, 0),
        merge(m.base.ids, m.spill.ids, -1),
        offs_b + offs_s,
    )


def snapshot(m: MutableIndex, cfg: GridConfig) -> GridIndex:
    """Freeze the current contents into a standard dense `GridIndex`.

    O(N) order-preserving merge, no argsort over N: live base slots are
    already cell-major (buckets) and keep their relative order; live spill
    records are stable-sorted by cell (arrival order within a cell) and
    interleaved AFTER the bucket records of their cell — exactly the order a
    stable `argsort(cell_id)` over the full point set would produce, which
    is what `build_index` does.  Bit-identical to a rebuild.
    """
    pts, crd, lab, ids, offsets = _merge_snapshot(m, cfg)
    n_out = int(m.n_live)
    index = GridIndex(
        proj=m.proj,
        points_sorted=pts[:n_out],
        coords_sorted=crd[:n_out],
        labels_sorted=lab[:n_out],
        ids_sorted=ids[:n_out],
        offsets=offsets,
        pyramid=m.pyramid,
        sat=None,
        pyr_tiles=m.pyr_tiles,
    )
    if cfg.counter == "sat":
        from repro.core import integral as integral_lib

        index = index._replace(sat=integral_lib.build_sat(m.pyramid[0]))
    return index


def quantized_snapshot(m: MutableIndex, cfg: GridConfig):
    """Freeze the current contents AND their int8 candidate store.

    Returns (GridIndex, quantized.QuantizedStore).  The store is a pure
    function of the snapshot and `snapshot` reproduces `build_index`'s CSR
    order bit-for-bit, so the mutability invariant extends to the quantized
    path with no incremental bookkeeping: requantizing after insert/delete
    yields EXACTLY the store a from-scratch rebuild would (the per-cell
    scales see identical bucket contents in identical order).  This is what
    the `pallas_q8` backend leans on — `build(P1).insert(P2)` serves
    bit-identical quantized results to `build(P1 ∪ P2)`
    (tests/test_quantized.py, tests/test_mutable.py).
    """
    from repro.core import quantized as qz

    index = snapshot(m, cfg)
    return index, qz.quantize_index(index, cfg)


def compact(
    m: MutableIndex,
    cfg: GridConfig,
    slack: float = 0.5,
    min_slack: int = 4,
    spill_capacity: int | None = None,
) -> MutableIndex:
    """Re-layout with fresh per-cell slack: spill merged back into buckets,
    tombstones reclaimed.  Order-preserving (snapshot's O(N) merge), so the
    searchable contents are unchanged; only the slack geometry moves."""
    return from_index(
        snapshot(m, cfg), cfg, slack=slack, min_slack=min_slack,
        spill_capacity=spill_capacity, next_id=int(m.next_id),
    )


def rebuild(m: MutableIndex, cfg: GridConfig, **layout_kw) -> MutableIndex:
    """Full from-scratch rebuild (the heavyweight escape hatch): re-sorts
    the surviving records with `build_index` instead of merging.  Exists as
    the always-correct fallback; `compact()` is the cheap path."""
    snap = snapshot(m, cfg)
    rebuilt = build_index(
        snap.points_sorted, cfg, m.proj,
        labels=snap.labels_sorted, ids=snap.ids_sorted,
    )
    return from_index(rebuilt, cfg, next_id=int(m.next_id), **layout_kw)


# ------------------------------------------------------------- validation ----


def validate_mutable(m: MutableIndex, cfg: GridConfig) -> dict[str, bool]:
    """Structural invariants of the mutable layout itself (slack accounting);
    `grid.validate_invariants(snapshot(m, cfg), cfg)` checks the searchable
    contents."""
    caps = m.cap_offsets[1:] - m.cap_offsets[:-1]
    used_ok = bool(jnp.all((m.used >= 0) & (m.used <= caps)))
    in_spill = jnp.arange(m.spill.ids.shape[0]) < m.spill_used
    live_total = int(jnp.sum(m.base.live)) + int(jnp.sum(m.spill.live & in_spill))
    # every live bucket slot sits inside its cell's handed-out prefix
    slot = jnp.arange(m.base.ids.shape[0], dtype=jnp.int32)
    c = jnp.clip(m.base.cell, 0, caps.shape[0] - 1)
    prefix_ok = bool(jnp.all(
        ~m.base.live
        | ((slot >= m.cap_offsets[c]) & (slot < m.cap_offsets[c] + m.used[c]))
    ))
    no_live_past_spill_used = bool(jnp.all(~m.spill.live | in_spill))
    pyramid_mass = all(int(level.sum()) == int(m.n_live) for level in m.pyramid)
    return {
        "used_within_capacity": used_ok,
        "live_matches_n_live": live_total == int(m.n_live),
        "live_slots_in_used_prefix": prefix_ok,
        "spill_live_in_prefix": no_live_past_spill_used,
        "pyramid_mass_is_n_live": pyramid_mass,
    }


# ------------------------------------------------------------ persistence ----


def state_to_tree(m: MutableIndex) -> dict[str, jax.Array]:
    """Flatten to a plain {name: array} dict (checkpoint-friendly: every
    value is an array, optional fields are encoded by key absence)."""
    out = {
        "proj/matrix": m.proj.matrix, "proj/lo": m.proj.lo, "proj/hi": m.proj.hi,
        "cap_offsets": m.cap_offsets, "used": m.used, "spilled": m.spilled,
        "spill_used": m.spill_used, "next_id": m.next_id, "n_live": m.n_live,
    }
    for slab, tag in ((m.base, "base"), (m.spill, "spill")):
        for field in Slab._fields:
            out[f"{tag}/{field}"] = getattr(slab, field)
    for lv, arr in enumerate(m.pyramid):
        out[f"pyramid/{lv}"] = arr
    if m.pyr_tiles is not None:
        out["pyr_tiles"] = m.pyr_tiles
    return out


def state_from_tree(tree: dict) -> MutableIndex:
    """Inverse of `state_to_tree` (accepts numpy or jax arrays)."""
    a = {k: jnp.asarray(v) for k, v in tree.items()}
    levels = sorted(
        int(k.split("/")[1]) for k in a if k.startswith("pyramid/")
    )
    slab = lambda tag: Slab(**{f: a[f"{tag}/{f}"] for f in Slab._fields})
    return MutableIndex(
        proj=Projection(a["proj/matrix"], a["proj/lo"], a["proj/hi"]),
        base=slab("base"),
        spill=slab("spill"),
        cap_offsets=a["cap_offsets"],
        used=a["used"],
        spilled=a["spilled"].astype(bool),
        spill_used=a["spill_used"],
        pyramid=tuple(a[f"pyramid/{lv}"] for lv in levels),
        pyr_tiles=a.get("pyr_tiles"),
        next_id=a["next_id"],
        n_live=a["n_live"],
    )
