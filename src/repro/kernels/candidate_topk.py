"""Pallas TPU kernel: fused candidate distances + streaming top-k.

After the radius loop, active search has <=C candidate points per query
(gathered from the CSR buckets).  This kernel fuses the distance computation
with k-selection so candidate distances never round-trip to HBM: distances
accumulate over d-chunks in a VMEM scratch, and the final chunk runs k
iterations of (min, argmin, mask) — k is small (<=64) so the unrolled select
beats a full sort by a wide margin.

Grid = (B, d_chunks); the d-chunk axis is the minormost (sequential on TPU),
so the scratch accumulator legally persists across chunk steps.
Validated with interpret=True against ref.candidate_topk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    cand_ref,   # (1, C, dc) float32
    q_ref,      # (1, dc) float32
    valid_ref,  # (1, C) int32
    outd_ref,   # (1, k) float32
    outi_ref,   # (1, k) int32
    acc_ref,    # scratch (1, C) float32
    *,
    k: int,
    nd: int,
    metric: str,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cand = cand_ref[0]                      # (C, dc)
    q = q_ref[...]                          # (1, dc)
    diff = cand - q                         # broadcast over C
    if metric == "l1":
        acc_ref[...] += jnp.sum(jnp.abs(diff), axis=1)[None, :]
    else:
        acc_ref[...] += jnp.sum(diff * diff, axis=1)[None, :]

    @pl.when(j == nd - 1)
    def _select():
        d = acc_ref[...]                    # (1, C)
        if metric != "l1":
            d = jnp.sqrt(jnp.maximum(d, 0.0))
        d = jnp.where(valid_ref[...] > 0, d, jnp.inf)
        col = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
        dists, idxs = [], []
        for _ in range(k):
            m = jnp.min(d, axis=1)          # (1,)
            am = jnp.argmin(d, axis=1)      # (1,)
            dists.append(m[0])
            idxs.append(jnp.where(jnp.isfinite(m[0]), am[0].astype(jnp.int32), -1))
            d = jnp.where(col == am[:, None], jnp.inf, d)
        outd_ref[0, :] = jnp.stack(dists)
        outi_ref[0, :] = jnp.stack(idxs)


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "d_chunk", "interpret")
)
def candidate_topk(
    candidates: jax.Array,  # (B, C, d) float32
    valid: jax.Array,       # (B, C) bool
    queries: jax.Array,     # (B, d) float32
    k: int,
    metric: str = "l2",
    d_chunk: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Contract identical to ref.candidate_topk."""
    b, c, d = candidates.shape
    dc = min(d_chunk, d)
    nd = -(-d // dc)
    d_pad = nd * dc
    if d_pad != d:
        candidates = jnp.pad(candidates, ((0, 0), (0, 0), (0, d_pad - d)))
        queries = jnp.pad(queries, ((0, 0), (0, d_pad - d)))

    kernel = functools.partial(_kernel, k=k, nd=nd, metric=metric)
    outd, outi = pl.pallas_call(
        kernel,
        grid=(b, nd),
        in_specs=[
            pl.BlockSpec((1, c, dc), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, dc), lambda i, j: (i, j)),
            pl.BlockSpec((1, c), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, c), jnp.float32)],
        interpret=interpret,
    )(
        candidates.astype(jnp.float32),
        queries.astype(jnp.float32),
        valid.astype(jnp.int32),
    )
    return outd, outi
