"""Multi-device behaviour (8 host devices via subprocess — the main test
process must keep the real 1-device view)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, timeout=420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_index_matches_single():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.core import distributed as D
        from repro.core import active_search as act, exact
        from repro.core.grid import GridConfig, build_index
        from repro.core.projection import identity_projection

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        rng = np.random.default_rng(0)
        pts = jnp.asarray(rng.normal(size=(4096, 2)), jnp.float32)
        cfg = GridConfig(grid_size=128, tile=16, window=48, row_cap=48, r0=6,
                         k_slack=2.0)
        proj = identity_projection(pts)
        sharded = D.build_sharded_index(pts, cfg, proj, mesh, "data")
        q = jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)
        res = D.sharded_search(sharded, cfg, q, 8, mesh, "data")
        ex = exact.knn(q, pts, 8)
        recall = np.mean([
            len(set(np.asarray(res.ids[i]).tolist())
                & set(np.asarray(ex.ids[i]).tolist())) / 8
            for i in range(16)
        ])
        assert recall > 0.85, recall
        print("recall", recall)
    """)


def test_sharded_backend_via_facade():
    """ActiveSearcher.build_sharded registers mesh+axis on the handle and the
    "sharded" backend merges per-shard searchers; results match the direct
    distributed.sharded_search call bit-for-bit."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro import api
        from repro.core import distributed as D
        from repro.core.grid import GridConfig
        from repro.core.projection import identity_projection

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        rng = np.random.default_rng(0)
        pts = jnp.asarray(rng.normal(size=(4096, 2)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 3, size=4096), jnp.int32)
        cfg = GridConfig(grid_size=128, tile=16, n_classes=3, window=48,
                         row_cap=48, r0=6, k_slack=2.0)
        proj = identity_projection(pts)
        s = api.ActiveSearcher.build_sharded(
            pts, mesh=mesh, axis="data", labels=labels, cfg=cfg, proj=proj)
        assert s.plan.backend == "sharded"
        q = D.replicate_queries(
            jnp.asarray(rng.normal(size=(16, 2)), jnp.float32), mesh)
        res = s.search(q, 8)
        want = D.sharded_search(s.index, cfg, q, 8, mesh, "data")
        for f in res._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(res, f)), np.asarray(getattr(want, f)),
                err_msg=f)
        preds = s.classify(q, 8)
        assert preds.shape == (16,)
        assert int(np.asarray(preds).min()) >= 0
        print("sharded facade ok")
    """)


def test_train_step_on_2x4_mesh():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.launch.mesh import make_host_mesh
        from repro.launch import steps as st
        from repro.optim import adamw

        cfg = get_smoke("internlm2-1.8b")
        mesh = make_host_mesh(2, 4)
        opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0)
        _, state_abs, state_sh, jit_for = st.make_train_step(
            cfg, opt_cfg, mesh, st.StepConfig(accum=2))
        state = st.init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg,
                                    st.StepConfig(accum=2), mesh)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)), jnp.int32),
        }
        babs = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch)
        with mesh:
            fn = jit_for(babs)
            losses = []
            for _ in range(3):
                state, m = fn(state, batch)
                losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        print("losses", losses)
    """)


def test_elastic_checkpoint_across_meshes(tmp_path):
    run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.launch.mesh import make_host_mesh
        from repro.launch import steps as st
        from repro.optim import adamw
        from repro.checkpoint.store import CheckpointManager

        cfg = get_smoke("internlm2-1.8b")
        sc = st.StepConfig()
        opt_cfg = adamw.AdamWConfig()
        mesh_a = make_host_mesh(2, 4)
        state = st.init_train_state(jax.random.PRNGKey(1), cfg, opt_cfg, sc, mesh_a)
        mgr = CheckpointManager({str(tmp_path)!r})
        mgr.save(1, state, blocking=True)

        mesh_b = make_host_mesh(4, 2)          # DIFFERENT mesh
        abstract = st.train_state_shapes(cfg, opt_cfg, sc)
        sh_b = st._ns(mesh_b, st.train_state_specs(abstract, cfg, mesh_b))
        restored = mgr.restore(1, abstract, shardings=sh_b)
        a = np.asarray(jax.device_get(state["params"]["embed"]))
        b = np.asarray(jax.device_get(restored["params"]["embed"]))
        np.testing.assert_array_equal(a, b)
        print("elastic OK")
    """)


def test_compressed_psum_shard_map():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compression import compressed_psum

        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("dp",))
        g = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)
        err = jnp.zeros((8, 64), jnp.float32)

        def f(g, e):
            out, new_e = compressed_psum(g[0], e[0], "dp")
            return out[None], new_e[None]

        fn = shard_map(f, mesh=mesh, in_specs=(P("dp"), P("dp")),
                       out_specs=(P("dp"), P("dp")), check_rep=False)
        mean_hat, err2 = fn(g, err)
        true_mean = np.asarray(g).mean(axis=0)
        got = np.asarray(mean_hat[0])
        scale = np.abs(np.asarray(g)).max() / 127
        np.testing.assert_allclose(got, true_mean, atol=8 * scale)
        print("compressed psum OK")
    """)
