"""minitron-8b [dense] — pruned Nemotron (arXiv:2407.14679; hf).

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
long_500k: SKIP natively (pure full attention); served via the beyond-paper
active-search retrieval-memory path (DESIGN.md §5)."""

from repro.models.config import ModelConfig, ParallelismPolicy

LONG_CONTEXT = "retrieval"

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
    rope_theta=1_000_000.0,
    # §Perf hillclimb (b): remat="dots" removes the fwd-recompute TP
    # all-reduces (X 4.77->4.09 s) and 21%% of compute; accum=16 keeps the
    # saved dot outputs inside 16 GiB HBM (8.2 GiB temp).
    policy=ParallelismPolicy(remat="dots", scan_layers=True, accum=16),
)

SMOKE = ModelConfig(
    name="minitron-8b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
)
