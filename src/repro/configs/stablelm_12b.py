"""stablelm-12b [dense] — (hf:stabilityai/stablelm-2-12b family; hf).

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
long_500k: SKIP (pure full attention)."""

from repro.models.config import ModelConfig, ParallelismPolicy

LONG_CONTEXT = "skip"

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    head_dim=160,
    policy=ParallelismPolicy(remat="full", scan_layers=True, accum=8),
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=16,
)
