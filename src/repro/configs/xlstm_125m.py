"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517; unverified).

12L d_model=768 4H d_ff=0 (xLSTM blocks carry their own projections),
vocab=50304.  Pattern: one sLSTM per 6 layers (offset 2), mLSTM elsewhere.
long_500k: NATIVE (recurrent state is O(1)/token)."""

from repro.models.config import ModelConfig, ParallelismPolicy, XLSTMConfig

LONG_CONTEXT = "native"

_PATTERN = tuple("slstm" if i == 2 else "mlstm" for i in range(6))

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    block_period=6,
    pattern=_PATTERN,
    xlstm=XLSTMConfig(n_heads=4, chunk=256),
    # 125M params: replicate them, shard the batch over every axis (pure DP).
    # TP here would shard nh=4 / hd=384 contraction dims -> all-reduce storms
    # (measured: 85 GiB temp, collective-bound; EXPERIMENTS.md §Perf).
    policy=ParallelismPolicy(dp_only=True, remat="dots", scan_layers=True),
)

SMOKE = ModelConfig(
    name="xlstm-125m-smoke",
    family="ssm",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    block_period=6,
    pattern=_PATTERN,
    xlstm=XLSTMConfig(n_heads=4, chunk=16),
)
