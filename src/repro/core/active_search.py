"""Active search for nearest neighbors — the paper's algorithm, end to end.

Pipeline per query (DESIGN.md §2):
  1. project the query into grid space (projection.py)
  2. adapt the radius with Eq. 1 over the count pyramid (pyramid.py)
  3. gather candidates from the CSR buckets inside a fixed window around the
     query cell (per-row contiguous slices — row-major cell ids make each
     window row ONE contiguous span of `points_sorted`)
  4. either return circle members (paper-faithful) or re-rank candidates by
     the true metric in the original space (refined mode)

All functions are jit/vmap friendly; fixed shapes throughout.
"""

from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import projection as proj_lib
from repro.core import pyramid as pyr
from repro.core.grid import GridConfig, GridIndex


class SearchResult(NamedTuple):
    ids: jax.Array        # (k,) int32 — global point ids (-1 where invalid)
    dists: jax.Array      # (k,) float32 — distance in the ORIGINAL space (inf where invalid)
    labels: jax.Array     # (k,) int32
    valid: jax.Array      # (k,) bool
    radius: jax.Array     # () int32 — final Eq.-1 radius (pixels)
    count: jax.Array      # () int32 — points inside the final circle
    iters: jax.Array      # () int32
    converged: jax.Array  # () bool — Eq. 1 hit the acceptance band
    truncated: jax.Array  # () bool — candidates were dropped: the circle
    # exceeded the candidate window, OR a window row held more than row_cap
    # points (the gather keeps only the first row_cap of each row's span)


class Candidates(NamedTuple):
    points: jax.Array   # (C, d) float32
    coords: jax.Array   # (C, 2) float32 grid coords
    labels: jax.Array   # (C,) int32
    ids: jax.Array      # (C,) int32
    valid: jax.Array    # (C,) bool


def _metric_dist(a: jax.Array, b: jax.Array, metric: str) -> jax.Array:
    diff = a - b
    if metric == "l1":
        return jnp.sum(jnp.abs(diff), axis=-1)
    return jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))


def majority_vote(labels: jax.Array, valid: jax.Array, n_classes: int) -> jax.Array:
    """(B, k) neighbor labels + validity -> (B,) argmax class votes.

    The one vote used by every classify path (jnp, pallas, sharded)."""

    def one(lab, ok):
        onehot = jax.nn.one_hot(lab, n_classes, dtype=jnp.float32)
        return jnp.argmax(jnp.sum(onehot * ok[:, None], axis=0)).astype(jnp.int32)

    return jax.vmap(one)(labels, valid)


def run_chunked(fn, queries, chunk_size: int | None):
    """Stream a batched query pipeline through fixed-size chunks.

    `queries` is an array — or any pytree of arrays sharing a leading batch
    axis (e.g. (q_grid, radii) pairs).  Calls `fn` on chunk_size-row slices
    (the last chunk is padded to full size by repeating its final row, so
    every kernel invocation keeps ONE static shape / VMEM footprint) and
    concatenates the per-chunk output pytrees.  Every query is computed
    exactly as in the unchunked call — all per-lane state in the pipeline is
    independent across the batch — so results are bit-identical for any
    chunk_size.
    """
    if chunk_size is not None and chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    b = jax.tree.leaves(queries)[0].shape[0]
    if b == 0:
        # An empty batch would otherwise reach the pipeline (or the
        # pad-by-last-row broadcast) with a zero-size leading axis; derive
        # the output pytree abstractly from a 1-row probe and return empty,
        # correctly-shaped leaves instead of invoking any kernel.
        probe = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct((1,) + a.shape[1:], a.dtype), queries
        )
        out = jax.eval_shape(fn, probe)
        return jax.tree.map(
            lambda s: jnp.zeros((0,) + s.shape[1:], s.dtype), out
        )
    if not chunk_size or b <= chunk_size:
        return fn(queries)
    outs = []
    for i in range(0, b, chunk_size):
        chunk = jax.tree.map(lambda a: a[i : i + chunk_size], queries)
        pad = chunk_size - jax.tree.leaves(chunk)[0].shape[0]
        if pad:
            chunk = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.broadcast_to(a[-1:], (pad,) + a.shape[1:])]
                ),
                chunk,
            )
        outs.append(fn(chunk))
    return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0)[:b], *outs)


def padded_csr(index: GridIndex, rcap: int):
    """CSR record arrays padded so a row_cap slice is always in bounds.

    Returns (points, coords, labels, ids, n, n_pad); pad ids are -1.
    """
    n = index.points_sorted.shape[0]
    pad = max(rcap - n, 0)
    if pad:
        pts = jnp.pad(index.points_sorted, ((0, pad), (0, 0)))
        crd = jnp.pad(index.coords_sorted, ((0, pad), (0, 0)))
        lab = jnp.pad(index.labels_sorted, (0, pad))
        ids = jnp.pad(index.ids_sorted, (0, pad), constant_values=-1)
    else:
        pts, crd, lab, ids = (
            index.points_sorted,
            index.coords_sorted,
            index.labels_sorted,
            index.ids_sorted,
        )
    return pts, crd, lab, ids, n, n + pad


def window_spans(index: GridIndex, cfg: GridConfig, q_grid: jax.Array):
    """CSR [start, end) spans of the w window rows around each query cell.

    q_grid (..., 2) -> start, end (..., w) — shape-polymorphic, so the same
    math serves the per-query path (q_grid (2,)) and the batched path
    (q_grid (B, 2), core/batched.py).
    """
    g = cfg.padded_size
    w = cfg.window
    cx = jnp.floor(q_grid[..., 0]).astype(jnp.int32)
    cy = jnp.floor(q_grid[..., 1]).astype(jnp.int32)
    x0 = jnp.clip(cx - w // 2, 0, g - w)
    y0 = jnp.clip(cy - w // 2, 0, g - w)
    rows = x0[..., None] + jnp.arange(w, dtype=jnp.int32)   # (..., w)
    start = index.offsets[rows * g + y0[..., None]]          # (..., w)
    end = index.offsets[rows * g + (y0[..., None] + w)]      # (..., w)
    return start, end


def gather_candidates(index: GridIndex, cfg: GridConfig, q_grid: jax.Array) -> Candidates:
    """Fixed-shape CSR gather of the window around the query cell.

    Window rows are contiguous spans of the CSR arrays (row-major cell ids),
    so each row costs one dynamic_slice of `row_cap` records.
    """
    w, rcap = cfg.window, cfg.row_cap
    d = index.points_sorted.shape[1]
    pts, crd, lab, ids, n, n_pad = padded_csr(index, rcap)
    start, end = window_spans(index, cfg, q_grid)            # (w,), (w,)

    def per_row(s, e):
        s_cl = jnp.clip(s, 0, max(n_pad - rcap, 0))
        j = s_cl + jnp.arange(rcap, dtype=jnp.int32)
        p = lax.dynamic_slice(pts, (s_cl, 0), (rcap, d))
        c = lax.dynamic_slice(crd, (s_cl, 0), (rcap, 2))
        lb = lax.dynamic_slice(lab, (s_cl,), (rcap,))
        gid = lax.dynamic_slice(ids, (s_cl,), (rcap,))
        ok = (j >= s) & (j < e) & (j < n)
        return p, c, lb, gid, ok

    p, c, lb, gid, ok = jax.vmap(per_row)(start, end)
    flat = lambda a: a.reshape((w * rcap,) + a.shape[2:])
    return Candidates(flat(p), flat(c), flat(lb), flat(gid), flat(ok))


def _topk_result(
    cand: Candidates,
    dists: jax.Array,
    k: int,
    stats: dict[str, jax.Array],
    truncated: jax.Array,
) -> SearchResult:
    masked = jnp.where(cand.valid, dists, jnp.inf)
    k_eff = min(k, masked.shape[0])
    neg_top, idx = lax.top_k(-masked, k_eff)
    if k_eff < k:  # k exceeds the candidate window: pad with invalid slots
        pad = k - k_eff
        neg_top = jnp.concatenate([neg_top, jnp.full((pad,), -jnp.inf)], axis=0)
        idx = jnp.concatenate([idx, jnp.zeros((pad,), idx.dtype)], axis=0)
    top_d = -neg_top
    sel_valid = jnp.isfinite(top_d)
    return SearchResult(
        ids=jnp.where(sel_valid, cand.ids[idx], -1),
        dists=top_d.astype(jnp.float32),
        labels=jnp.where(sel_valid, cand.labels[idx], -1),
        valid=sel_valid,
        radius=stats["radius"],
        count=stats["count"],
        iters=stats["iters"],
        converged=stats["converged"],
        truncated=truncated,
    )


@partial(jax.jit, static_argnames=("cfg", "k", "mode", "adaptive_r0"))
def search_one(
    index: GridIndex, cfg: GridConfig, query: jax.Array, k: int,
    mode: str = "refined", adaptive_r0: bool = False,
) -> SearchResult:
    """Active search for ONE query point (original space, shape (d,)).

    mode="paper":   members of the final circle, ranked by grid-pixel distance
                    (the paper returns the circle contents when n == k).
    mode="refined": candidates re-ranked by the true metric in the original
                    space (exact kNN restricted to the window; recommended).
    adaptive_r0:    seed Eq. 1 from the pyramid's local-density sketch
                    (pyramid.seed_radius) instead of the global cfg.r0.
    """
    q_grid = proj_lib.to_grid_coords(index.proj, query, cfg.grid_size)
    stats = pyr.radius_search(index, cfg, q_grid, k, adaptive_r0=adaptive_r0)
    r = stats["radius"]
    # the flag must fire whenever candidates were DROPPED: circle wider than
    # the window, or a window row overflowing its row_cap slice (same rule,
    # same span math, as the batched backends)
    start, end = window_spans(index, cfg, q_grid)
    truncated = ((2 * r + 1) > jnp.int32(cfg.window)) | jnp.any(
        end - start > jnp.int32(cfg.row_cap)
    )

    cand = gather_candidates(index, cfg, q_grid)
    if mode == "paper":
        centers = jnp.floor(cand.coords) + 0.5
        gd = _metric_dist(centers, q_grid[None, :], cfg.metric)
        in_circle = gd <= r.astype(jnp.float32)
        cand = cand._replace(valid=cand.valid & in_circle)
        return _topk_result(cand, gd, k, stats, truncated)

    dists = _metric_dist(cand.points, query[None, :].astype(jnp.float32), cfg.metric)
    return _topk_result(cand, dists, k, stats, truncated)


@partial(jax.jit, static_argnames=("cfg", "k", "mode", "adaptive_r0"))
def _search_jnp(
    index: GridIndex, cfg: GridConfig, queries: jax.Array, k: int,
    mode: str = "refined", adaptive_r0: bool = False,
) -> SearchResult:
    return jax.vmap(
        lambda q: search_one(index, cfg, q, k, mode, adaptive_r0)
    )(queries)


def _deprecated_searcher(index, cfg, backend, interpret, chunk_size, what):
    """Shared shim plumbing: warn once per call site, build the facade."""
    from repro.core import engine

    warnings.warn(
        f"active_search.{what}(backend=/interpret=/chunk_size=) is "
        f"deprecated; build a repro.api.ActiveSearcher with an "
        f"ExecutionPlan instead (results are bit-identical)",
        DeprecationWarning,
        stacklevel=3,
    )
    plan = engine.ExecutionPlan(
        backend=backend, interpret=interpret, chunk_size=chunk_size
    )
    return engine.ActiveSearcher.from_index(index, cfg, plan=plan)


def search(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    backend: str = "jnp",
    interpret: bool | None = None,
    chunk_size: int | None = None,
) -> SearchResult:
    """DEPRECATED shim — use `repro.api.ActiveSearcher.search`.

    Delegates to the facade (`core/engine.py`), which resolves `backend`
    from the registry and carries interpret/chunk_size in an ExecutionPlan;
    results are bit-identical to the pre-facade path.  Kept so existing
    call sites and tests keep passing.
    """
    return _deprecated_searcher(
        index, cfg, backend, interpret, chunk_size, "search"
    ).search(queries, k, mode=mode)


@partial(jax.jit, static_argnames=("cfg", "k", "mode", "adaptive_r0"))
def _classify_jnp(
    index: GridIndex, cfg: GridConfig, queries: jax.Array, k: int,
    mode: str = "refined", adaptive_r0: bool = False,
) -> jax.Array:
    if cfg.n_classes <= 0:
        raise ValueError("classify() needs an index built with n_classes > 0")

    if mode == "paper":

        def one(q):
            q_grid = proj_lib.to_grid_coords(index.proj, q, cfg.grid_size)
            stats = pyr.radius_search(
                index, cfg, q_grid, k, adaptive_r0=adaptive_r0
            )
            counts = pyr.count_in_circle(index, cfg, q_grid, stats["radius"])
            return jnp.argmax(counts).astype(jnp.int32)

        return jax.vmap(one)(queries)

    res = _search_jnp(index, cfg, queries, k, mode="refined",
                      adaptive_r0=adaptive_r0)
    refined = majority_vote(res.labels, res.valid, cfg.n_classes)

    # graceful degradation: when the data is so sparse that the Eq.-1 circle
    # outruns the candidate window (res.truncated / <k valid candidates), the
    # window vote is under-sampled — fall back to the paper's count-based
    # argmax at the final radius for THOSE queries only.
    def count_pred(q, r):
        q_grid = proj_lib.to_grid_coords(index.proj, q, cfg.grid_size)
        return jnp.argmax(pyr.count_in_circle(index, cfg, q_grid, r)).astype(jnp.int32)

    fallback = jax.vmap(count_pred)(queries, res.radius)
    short = jnp.sum(res.valid.astype(jnp.int32), axis=1) < k
    return jnp.where(short | res.truncated, fallback, refined)


def classify(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    backend: str = "jnp",
    interpret: bool | None = None,
    chunk_size: int | None = None,
) -> jax.Array:
    """DEPRECATED shim — use `repro.api.ActiveSearcher.classify`.

    mode="paper":   argmax of per-class counts inside the final circle — pure
                    count comparison on the class channels, exactly Fig. 2.
    mode="refined": majority vote over the refined top-k labels.
    Delegates to the facade (`core/engine.py`); bit-identical results.
    """
    return _deprecated_searcher(
        index, cfg, backend, interpret, chunk_size, "classify"
    ).classify(queries, k, mode=mode)
