"""Decoder LM composition serving all 10 assigned architectures.

Layers are stored STACKED BY PERIOD POSITION: `params["blocks"][p]` holds the
params of period-position p with a leading (n_repeat,) axis, so homogeneous
stacks run under ONE lax.scan (fast compiles at 40+ layers) and heterogeneous
patterns (jamba 1:7 attn:mamba, xlstm m/sLSTM mix) scan over the repeating
period.  policy.scan_layers=False unrolls instead (used to cross-check
cost_analysis FLOP accounting in the dry-run).

Modes: forward() for training, prefill() -> cache, decode_step() for serving,
decode_step_retrieved() for the active-search long-context path.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba as mam
from repro.models import moe as moe_lib
from repro.models import xlstm as xl
from repro.models.config import ModelConfig
from repro.parallel.axes import constrain

Params = dict[str, Any]


# ------------------------------------------------------------------- init ---


def _init_layer(key, cfg: ModelConfig, p: int) -> dict:
    kind = cfg.pattern[p]
    k1, k2, k3 = jax.random.split(key, 3)
    layer: dict = {"norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    if kind == "attn":
        layer["core"] = attn.init_attention(k1, cfg)
    elif kind == "mamba":
        layer["core"] = mam.init_mamba(k1, cfg)
    elif kind == "mlstm":
        layer["core"] = xl.init_mlstm(k1, cfg)
    elif kind == "slstm":
        layer["core"] = xl.init_slstm(k1, cfg)
    else:
        raise ValueError(kind)
    if cfg.is_moe_layer(p):
        layer["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        layer["ffn"] = moe_lib.init_moe(k2, cfg)
    elif cfg.d_ff > 0 and kind in ("attn", "mamba"):
        layer["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        layer["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff)
    return layer


def init_params(key, cfg: ModelConfig) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = []
    for p in range(cfg.block_period):
        stack = [
            _init_layer(keys[r * cfg.block_period + p], cfg, p)
            for r in range(cfg.n_repeat)
        ]
        blocks.append(jax.tree.map(lambda *a: jnp.stack(a), *stack))
    params: Params = {
        "embed": L.embed_init(keys[-1], (cfg.vocab_eff, cfg.d_model)),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[-2], (cfg.d_model, cfg.vocab_eff))
    return params


def _mask_pad_vocab(cfg: ModelConfig, logits: jax.Array) -> jax.Array:
    """Padded vocab rows can never win argmax / receive CE mass."""
    if cfg.vocab_eff == cfg.vocab_size:
        return logits
    col = jnp.arange(cfg.vocab_eff) < cfg.vocab_size
    return jnp.where(col, logits, jnp.asarray(-1e30, logits.dtype))


# ---------------------------------------------------------------- forward ---


def _apply_layer_train(layer, cfg: ModelConfig, p: int, x, positions):
    kind = cfg.pattern[p]
    h = L.rms_norm(x, layer["norm1"], cfg.norm_eps)
    if kind == "attn":
        core = attn.attention_block(
            layer["core"], cfg, h, positions, chunk=cfg.policy.attn_chunk
        )
    elif kind == "mamba":
        core = mam.mamba_block(layer["core"], cfg, h)
    elif kind == "mlstm":
        core = xl.mlstm_block(layer["core"], cfg, h)
    else:
        core = xl.slstm_block(layer["core"], cfg, h)
    x = constrain(x + core, "batch", "seq", "embed")
    aux = jnp.float32(0.0)
    if "ffn" in layer:
        h2 = L.rms_norm(x, layer["norm2"], cfg.norm_eps)
        if cfg.is_moe_layer(p):
            y, aux = moe_lib.moe_block(layer["ffn"], cfg, h2)
        else:
            f = layer["ffn"]
            y = L.swiglu(h2, f["wi"], f["wg"], f["wo"])
        x = constrain(x + y, "batch", "seq", "embed")
    return x, aux


def _remat(fn, cfg: ModelConfig):
    if cfg.policy.remat == "none":
        return fn
    if cfg.policy.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def embed_inputs(params: Params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Token embedding + modality frontend stubs (DESIGN.md §6)."""
    if cfg.frontend == "audio":
        # EnCodec frame embeddings arrive precomputed: (B, S, d)
        return constrain(
            batch["frame_embeds"].astype(L.ACT_DTYPE), "batch", "seq", "embed"
        )
    x = params["embed"][batch["tokens"]].astype(L.ACT_DTYPE)
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        # patch embeddings occupy the first n_frontend_tokens positions
        ve = batch["vision_embeds"].astype(L.ACT_DTYPE)
        x = lax.dynamic_update_slice(x, ve, (0, 0, 0))
    return constrain(x, "batch", "seq", "embed")


def forward(params: Params, cfg: ModelConfig, batch: dict) -> tuple[jax.Array, jax.Array]:
    """Training forward: batch {tokens (B,S), ...} -> (logits (B,S,V), aux)."""
    x = embed_inputs(params, cfg, batch)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(x, block_slice):
        aux = jnp.float32(0.0)
        for p in range(cfg.block_period):
            x, a = _apply_layer_train(block_slice[p], cfg, p, x, positions)
            aux = aux + a
        return x, aux

    body = _remat(body, cfg)

    if cfg.policy.scan_layers and cfg.n_repeat > 1:
        x, auxs = lax.scan(lambda c, b: body(c, b), x, params["blocks"])
        aux = jnp.sum(auxs)
    else:
        aux = jnp.float32(0.0)
        for r in range(cfg.n_repeat):
            blk = [jax.tree.map(lambda a: a[r], params["blocks"][p]) for p in range(cfg.block_period)]
            x, a = body(x, blk)
            aux = aux + a

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = _mask_pad_vocab(cfg, jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype)))
    return constrain(logits, "batch", "seq", "vocab"), aux


def loss_fn(
    params: Params, cfg: ModelConfig, batch: dict, aux_weight: float = 0.01
) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, batch)
    mask = batch.get("mask")
    nll = L.softmax_cross_entropy(logits, batch["labels"], mask)
    loss = nll + aux_weight * aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------- serving ---


def _apply_layer_prefill(layer, cfg, p, x, positions, cache_len):
    kind = cfg.pattern[p]
    h = L.rms_norm(x, layer["norm1"], cfg.norm_eps)
    if kind == "attn":
        core, cache = attn.prefill_cache(layer["core"], cfg, h, positions, cache_len)
    elif kind == "mamba":
        core, cache = mam.mamba_prefill(layer["core"], cfg, h)
    elif kind == "mlstm":
        core, cache = xl.mlstm_prefill(layer["core"], cfg, h)
    else:
        core, cache = xl.slstm_prefill(layer["core"], cfg, h)
    x = constrain(x + core, "batch", "seq", "embed")
    if "ffn" in layer:
        h2 = L.rms_norm(x, layer["norm2"], cfg.norm_eps)
        if cfg.is_moe_layer(p):
            y, _ = moe_lib.moe_block(layer["ffn"], cfg, h2)
        else:
            f = layer["ffn"]
            y = L.swiglu(h2, f["wi"], f["wg"], f["wo"])
        x = constrain(x + y, "batch", "seq", "embed")
    return x, cache


def prefill(
    params: Params, cfg: ModelConfig, batch: dict, cache_len: int = 0
) -> tuple[jax.Array, list, jax.Array]:
    """Prefill: -> (last-position logits (B, V), caches, last hidden (B, d)).

    caches: list over period positions; leaves have leading (n_repeat,) axis
    (matching the stacked param layout)."""
    x = embed_inputs(params, cfg, batch)
    b, s, _ = x.shape
    cache_len = cache_len or s
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(x, block_slice):
        caches = []
        for p in range(cfg.block_period):
            x, c = _apply_layer_prefill(block_slice[p], cfg, p, x, positions, cache_len)
            caches.append(c)
        return x, caches

    body = _remat(body, cfg)

    if cfg.policy.scan_layers and cfg.n_repeat > 1:
        x, caches = lax.scan(lambda c, blk: body(c, blk), x, params["blocks"])
    else:
        all_caches = []
        for r in range(cfg.n_repeat):
            blk = [jax.tree.map(lambda a: a[r], params["blocks"][p]) for p in range(cfg.block_period)]
            x, cs = body(x, blk)
            all_caches.append(cs)
        caches = jax.tree.map(lambda *a: jnp.stack(a), *all_caches)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1, :]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = _mask_pad_vocab(cfg, jnp.einsum("bd,dv->bv", last, head.astype(x.dtype)))
    return constrain(logits, "batch", "vocab"), caches, last


def _apply_layer_decode(layer, cfg, p, x, cache, pos, retrieved=None):
    kind = cfg.pattern[p]
    h = L.rms_norm(x, layer["norm1"], cfg.norm_eps)
    if kind == "attn":
        if retrieved is not None:
            core, cache = attn.decode_attention_retrieved(
                layer["core"], cfg, h, cache, pos, retrieved[0], retrieved[1], retrieved[2]
            )
        else:
            core, cache = attn.decode_attention(layer["core"], cfg, h, cache, pos)
    elif kind == "mamba":
        core, cache = mam.mamba_decode_step(layer["core"], cfg, h, cache)
    elif kind == "mlstm":
        core, cache = xl.mlstm_decode_step(layer["core"], cfg, h, cache)
    else:
        core, cache = xl.slstm_decode_step(layer["core"], cfg, h, cache)
    x = constrain(x + core, "batch", "seq", "embed")
    if "ffn" in layer:
        h2 = L.rms_norm(x, layer["norm2"], cfg.norm_eps)
        if cfg.is_moe_layer(p):
            y, _ = moe_lib.moe_block(layer["ffn"], cfg, h2)
        else:
            f = layer["ffn"]
            y = L.swiglu(h2, f["wi"], f["wg"], f["wo"])
        x = constrain(x + y, "batch", "seq", "embed")
    return x, cache


def decode_step(
    params: Params,
    cfg: ModelConfig,
    caches: list,
    token: jax.Array,   # (B,) int32
    pos: jax.Array,     # () int32
    retrieved: tuple | None = None,  # (positions (B,m), valid (B,m), local_window)
) -> tuple[jax.Array, list, jax.Array]:
    """One decode step -> (logits (B, V), caches, hidden (B, d))."""
    x = params["embed"][token][:, None, :].astype(L.ACT_DTYPE)

    def body(x, inp):
        block_slice, cache_slice = inp
        new_caches = []
        for p in range(cfg.block_period):
            x, c = _apply_layer_decode(
                block_slice[p], cfg, p, x, cache_slice[p], pos, retrieved
            )
            new_caches.append(c)
        return x, new_caches

    if cfg.policy.scan_layers and cfg.n_repeat > 1:
        x, caches = lax.scan(body, x, (params["blocks"], caches))
    else:
        all_caches = []
        for r in range(cfg.n_repeat):
            blk = [jax.tree.map(lambda a: a[r], params["blocks"][p]) for p in range(cfg.block_period)]
            cs = [jax.tree.map(lambda a: a[r], caches[p]) for p in range(cfg.block_period)]
            x, ncs = body(x, (blk, cs))
            all_caches.append(ncs)
        caches = jax.tree.map(lambda *a: jnp.stack(a), *all_caches)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    hidden = x[:, 0, :]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = _mask_pad_vocab(cfg, jnp.einsum("bd,dv->bv", hidden, head.astype(x.dtype)))
    return constrain(logits, "batch", "vocab"), caches, hidden


def init_caches(cfg: ModelConfig, batch: int, cache_len: int) -> list:
    """Empty decode caches with the same structure prefill() produces."""
    caches = []
    for p in range(cfg.block_period):
        kind = cfg.pattern[p]
        if kind == "attn":
            c = {
                "k": jnp.zeros((batch, cache_len, cfg.hkv_eff, cfg.head_dim), L.ACT_DTYPE),
                "v": jnp.zeros((batch, cache_len, cfg.hkv_eff, cfg.head_dim), L.ACT_DTYPE),
            }
        elif kind == "mamba":
            c = mam.init_mamba_cache(cfg, batch)
        elif kind == "mlstm":
            c = xl.init_mlstm_cache(cfg, batch)
        else:
            c = xl.init_slstm_cache(cfg, batch)
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_repeat,) + a.shape), c))
    return caches
