"""Kernel microbench: the pure-JAX reference paths (what actually executes on
CPU) timed across sizes, plus one interpret-mode validation per Pallas kernel
(interpret=True timings are NOT hardware-meaningful — correctness only).

The stacked-vs-level-scheduled counting comparison IS meaningful on CPU
interpret: both paths pay the same per-program emulation cost, so the ratio
reflects the kernel-invocation count (L stacked passes vs one scheduled
pass).  Results land in BENCH_kernels.json (see REPRO_BENCH_ARTIFACTS) so CI
records the perf trajectory.

Env knobs:
  REPRO_BENCH_QUICK=1      shrink sweeps to CI-friendly sizes
  REPRO_BENCH_ARTIFACTS=D  directory for BENCH_kernels.json (default ".")
"""

from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, timeit
from repro.kernels import ops, ref


def _quick() -> bool:
    return os.environ.get("REPRO_BENCH_QUICK", "0") == "1"


def main() -> None:
    rng = np.random.default_rng(0)
    csv = Csv("kernel,config,ref_us_per_call,pallas_interpret_ok")
    results: dict = {"schema": 1, "timestamp": time.time(), "quick": _quick()}

    # tile_count: one pyramid-level circle count
    sizes = ((256, 16, 1),) if _quick() else ((256, 16, 1), (1024, 16, 4))
    for s, tile, c in sizes:
        level = jnp.asarray(rng.integers(0, 4, size=(s, s, c)), jnp.int32)
        q = jnp.asarray(rng.uniform(0, s, size=(64, 2)), jnp.float32)
        r = jnp.asarray(rng.uniform(1, tile / 2 - 1.5, size=(64,)), jnp.float32)
        t = timeit(lambda: ref.tile_count(level, q, r, 1, tile), repeats=5)
        ok = bool(np.array_equal(
            np.asarray(ops.tile_count(level, q, r, 1, tile, interpret=True)),
            np.asarray(ref.tile_count(level, q, r, 1, tile)),
        ))
        csv.row("tile_count", f"S={s} T={tile} C={c} B=64", f"{t*1e6/64:.1f}", ok)

    # candidate_topk: post-gather re-rank
    shapes = ((64, 256, 64, 16),) if _quick() else \
        ((64, 256, 64, 16), (256, 1024, 128, 16))
    for b, c, d, k in shapes:
        cand = jnp.asarray(rng.normal(size=(b, c, d)), jnp.float32)
        valid = jnp.asarray(rng.uniform(size=(b, c)) > 0.2)
        q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
        t = timeit(lambda: ref.candidate_topk(cand, valid, q, k), repeats=5)
        gd, _ = ops.candidate_topk(cand[:4], valid[:4], q[:4], k, interpret=True)
        wd, _ = ref.candidate_topk(cand[:4], valid[:4], q[:4], k)
        ok = bool(np.allclose(np.asarray(gd), np.asarray(wd), atol=1e-4))
        csv.row("candidate_topk", f"B={b} C={c} d={d} k={k}", f"{t*1e6/b:.1f}", ok)

    # brute_knn: the paper's baseline
    brute = ((100, 10_000, 2, 11),) if _quick() else \
        ((100, 10_000, 2, 11), (100, 100_000, 2, 11))
    for b, n, d, k in brute:
        q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        t = timeit(lambda: ref.brute_knn(q, x, k), repeats=3)
        gd, _ = ops.brute_knn(q[:4], x[:2048], k, interpret=True)
        wd, _ = ref.brute_knn(q[:4], x[:2048], k)
        ok = bool(np.allclose(np.asarray(gd), np.asarray(wd), atol=1e-4))
        csv.row("brute_knn", f"B={b} N={n} d={d} k={k}", f"{t*1e6/b:.1f}", ok)

    results["count_paths"] = bench_count_paths(rng, csv)
    results["candidate_paths"] = bench_candidate_paths(rng, csv)
    if not _quick():
        results["search_backends"] = bench_search_backends(rng, csv)

    art_dir = os.environ.get("REPRO_BENCH_ARTIFACTS", ".")
    path = os.path.join(art_dir, "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_kernels] wrote {path}", flush=True)
    return csv


def bench_count_paths(rng, csv: Csv) -> dict:
    """Stacked (L x tile_count + select) vs level-scheduled
    (tile_count_multilevel) counting — the Eq.-1 loop body.

    Config note: the CPU interpreter charges every grid program a copy of
    every operand (the operands ride in its while_loop carry), a cost real
    hardware does not pay — on TPU the index_map DMAs only the addressed
    (T, T, C) blocks.  A VMEM-scale pyramid keeps that artifact small, so
    the ratio below reflects what the scheduler actually removes: L
    pallas_calls-worth of programs per Eq.-1 iteration vs one.

    Both count paths run through the facade: the stacked baseline is the
    registered count-only backend "pallas_stacked"."""
    from repro.api import ActiveSearcher, ExecutionPlan, GridConfig, identity_projection

    # same config in quick mode: smaller sweeps time too few programs to
    # measure reliably, and this one still finishes in seconds
    b, grid, tile = 128, 128, 8
    cfg = GridConfig(grid_size=grid, tile=tile, window=32,
                     row_cap=32, r0=10, k_slack=2.0)
    n = 5_000
    pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    multi = ActiveSearcher.build(
        pts, cfg=cfg, proj=identity_projection(pts),
        plan=ExecutionPlan(backend="pallas", interpret=True),
    )
    stacked = multi.with_plan(backend="pallas_stacked")
    q = jnp.asarray(rng.normal(size=(b, 2)), jnp.float32)
    radii = jnp.asarray(rng.integers(1, cfg.max_radius, size=b), jnp.int32)

    # one pass is only ~5-15 ms, so generous repeats keep the median stable
    # against scheduler noise at negligible cost
    t_stack = timeit(lambda: stacked.count_at(q, radii), repeats=25, warmup=3)
    t_multi = timeit(lambda: multi.count_at(q, radii), repeats=25, warmup=3)
    parity = bool(np.array_equal(
        np.asarray(multi.count_at(q, radii)),
        np.asarray(stacked.count_at(q, radii)),
    ))
    out = {
        "levels": cfg.levels,
        "batch": b,
        "grid_size": grid,
        "tile": tile,
        "stacked_counts_per_s": b / t_stack,
        "level_scheduled_counts_per_s": b / t_multi,
        "speedup": t_stack / t_multi,
        "parity": parity,
    }
    csv.row("counts_stacked", f"L={cfg.levels} B={b} G={grid} T={tile}",
            f"{t_stack*1e6/b:.1f}", parity)
    csv.row("counts_level_scheduled", f"L={cfg.levels} B={b} G={grid} T={tile}",
            f"{t_multi*1e6/b:.1f}", parity)
    print(f"[bench_kernels] level scheduler speedup over stacked "
          f"(L={cfg.levels}): {out['speedup']:.2f}x", flush=True)
    return out


def bench_candidate_paths(rng, csv: Csv) -> dict:
    """Fused csr_candidate_topk vs the gather pipeline (one-shot window
    gather + dense candidate_topk) — the candidate stage in isolation.

    The CPU interpreter emulates the fused kernel's per-row DMAs element by
    element, so the interpret-mode RATIO is not hardware-meaningful (unlike
    count_paths) — run this sweep with REPRO_PALLAS_INTERPRET=0 on a TPU to
    read the real speedup.  What IS meaningful everywhere: the recorded
    bit-parity of (dists, global indices) between the two paths, and the
    candidate-stage HBM intermediate each needs — the gather path
    materializes (B, w*row_cap) x four record fields; the fused path writes
    only the (B, k) result pair."""
    n, d, b, w, rcap, k = (10_000, 8, 8, 16, 16, 8) if _quick() else \
        (100_000, 16, 32, 32, 32, 16)
    store = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    starts = jnp.asarray(rng.integers(0, n - rcap, size=(b, w)), jnp.int32)
    ends = jnp.minimum(
        starts + jnp.asarray(rng.integers(0, rcap + 4, size=(b, w)), jnp.int32),
        n,
    )

    def fused():
        return ops.csr_candidate_topk(
            store, starts, ends, q, k, n, rcap, interpret=True
        )

    def gather():
        s_cl = jnp.clip(starts, 0, n - rcap)
        j = s_cl[:, :, None] + jnp.arange(rcap, dtype=jnp.int32)
        ok = (j >= starts[:, :, None]) & (j < ends[:, :, None]) & (j < n)
        flat = j.reshape(b, w * rcap)
        cand = jnp.take(store, flat, axis=0)
        dd, di = ops.candidate_topk(
            cand, ok.reshape(b, w * rcap), q, k, d_chunk=d, interpret=True
        )
        dgi = jnp.where(
            di >= 0, jnp.take_along_axis(flat, jnp.maximum(di, 0), axis=1), -1
        )
        return dd, dgi

    t_fused = timeit(lambda: fused()[0], repeats=5, warmup=1)
    t_gather = timeit(lambda: gather()[0], repeats=5, warmup=1)
    # the inter-kernel bit contract (fused == gather+dense candidate_topk,
    # global indices included), checked on the SAME closures that were just
    # timed — exact at ANY d, unlike the big-tensor jnp oracle which can sit
    # 1 ulp away at larger d (see tests/test_kernels.py)
    gd, gi = fused()
    dd, dgi = gather()
    parity = bool(np.array_equal(np.asarray(gd), np.asarray(dd))
                  and np.array_equal(np.asarray(gi), np.asarray(dgi)))
    # per-field record bytes of the pipeline-level intermediate: points(f32 d)
    # + coords(f32 2) + labels(i32) + ids(i32) + valid(bool)
    gather_bytes = b * w * rcap * (4 * d + 8 + 4 + 4 + 1)
    fused_bytes = b * k * (4 + 4)
    out = {
        "n": n, "d": d, "batch": b, "window": w, "row_cap": rcap, "k": k,
        "fused_cands_per_s": b / t_fused,
        "gather_cands_per_s": b / t_gather,
        "gather_intermediate_bytes": gather_bytes,
        "fused_intermediate_bytes": fused_bytes,
        "intermediate_bytes_reduction": gather_bytes / fused_bytes,
        "parity": parity,
    }
    csv.row("candidate_fused_csr_topk", f"N={n} B={b} w={w} cap={rcap} k={k}",
            f"{t_fused*1e6/b:.1f}", parity)
    csv.row("candidate_gather_topk", f"N={n} B={b} w={w} cap={rcap} k={k}",
            f"{t_gather*1e6/b:.1f}", parity)
    print(f"[bench_kernels] candidate-stage intermediate bytes: "
          f"{gather_bytes:,} (gather) -> {fused_bytes:,} (fused), "
          f"{out['intermediate_bytes_reduction']:.0f}x smaller", flush=True)
    return out


def bench_search_backends(rng, csv: Csv) -> list[dict]:
    """End-to-end active search: per-query vmap path vs the batched
    kernel-backed pipeline (core/batched.py).  On CPU the pallas backend runs
    interpret-mode, so its ABSOLUTE time is not hardware-meaningful — the row
    pairs exist so the same sweep on a TPU (REPRO_PALLAS_INTERPRET=0) reads
    out the real speedup; the end-of-row flag re-checks result parity."""
    from repro.api import ActiveSearcher, GridConfig, identity_projection

    k = 11
    rows = []
    cfg = GridConfig(grid_size=256, tile=16, n_classes=3, window=32,
                     row_cap=32, r0=10, k_slack=2.0)
    for n, b in ((20_000, 64), (100_000, 256)):
        pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 3, size=n), jnp.int32)
        vmap_s = ActiveSearcher.build(
            pts, labels=labels, cfg=cfg, proj=identity_projection(pts)
        )
        pallas_s = vmap_s.with_plan(backend="pallas")
        q = jnp.asarray(rng.normal(size=(b, 2)), jnp.float32)
        t_vmap = timeit(lambda: vmap_s.search(q, k).ids, repeats=3)
        t_pal = timeit(lambda: pallas_s.search(q, k).ids, repeats=3, warmup=1)
        a = vmap_s.search(q, k)
        p = pallas_s.search(q, k)
        ok = bool(np.array_equal(np.asarray(a.ids), np.asarray(p.ids)))
        csv.row("search_vmap_jnp", f"N={n} B={b} k={k}", f"{t_vmap*1e6/b:.1f}", ok)
        csv.row("search_batched_pallas", f"N={n} B={b} k={k}", f"{t_pal*1e6/b:.1f}", ok)
        rows.append({"n": n, "batch": b, "k": k, "jnp_s": t_vmap,
                     "pallas_interpret_s": t_pal, "parity": ok})
    return rows


if __name__ == "__main__":
    main()
