"""Sharding rules: fit_pspec properties + full-tree spec coverage."""

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as hst
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.models import model as M
from repro.parallel import sharding as sh


class FakeMesh:
    """Shape-only stand-in (fit_pspec/param_specs never touch devices)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _prod(axes):
    out = 1
    for a in axes:
        out *= MESH.shape[a]
    return out


def test_fit_keeps_divisible():
    assert sh.fit_pspec(P("data", "model"), (32, 64), MESH) == P("data", "model")


def test_fit_rehomes_to_free_dim():
    # kv=8 cannot take model=16 -> moves to head_dim=128 (dim0 is occupied)
    got = sh.fit_pspec(P("data", "model", None), (4096, 8, 128), MESH)
    assert got == P("data", None, "model")
    # with dim0 free, first-fit re-homes there instead — still legal
    got2 = sh.fit_pspec(P(None, "model", None), (4096, 8, 128), MESH)
    assert got2 == P("model", None, None)


def test_fit_drops_when_nothing_fits():
    got = sh.fit_pspec(P("model",), (7,), MESH)
    assert got == P(None)


def test_fit_multi_axis_entry():
    got = sh.fit_pspec(P(("pod", "data"), None), (64, 10), MESH3)
    assert got == P(("pod", "data"), None)
    # dim0=10 keeps 'pod' (2 | 10); 'data' re-homes to dim1 (16 | 64)
    got2 = sh.fit_pspec(P(("pod", "data"), None), (10, 64), MESH3)
    assert got2 == P("pod", "data")


@settings(max_examples=50, deadline=None)
@given(
    dims=hst.lists(hst.integers(1, 512), min_size=1, max_size=4),
    seed=hst.integers(0, 2**31 - 1),
)
def test_fit_always_legal(dims, seed):
    """Post-fit, every sharded dim divides the product of its axes."""
    rng = np.random.default_rng(seed)
    names = ["data", "model", "pod"]
    entries = [
        None if rng.random() < 0.4 else names[rng.integers(0, 3)]
        for _ in dims
    ]
    # dedupe axis usage
    seen = set()
    for i, e in enumerate(entries):
        if e in seen:
            entries[i] = None
        elif e is not None:
            seen.add(e)
    spec = P(*entries)
    got = sh.fit_pspec(spec, tuple(dims), MESH3)
    used = set()
    for size, entry in zip(dims, tuple(got) + (None,) * (len(dims) - len(got))):
        axes = (
            () if entry is None
            else (entry,) if isinstance(entry, str) else tuple(entry)
        )
        prod = 1
        for a in axes:
            assert a not in used
            used.add(a)
            prod *= MESH3.shape[a]
        assert size % prod == 0, (size, axes)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_specs_cover_and_divide(arch):
    """Every param leaf gets a legal spec on the production mesh shape."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    specs = sh.param_specs(params, cfg, MESH)
    leaves_p = jax.tree.leaves(params)
    leaves_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_p) == len(leaves_s)
    for leaf, spec in zip(leaves_p, leaves_s):
        for size, entry in zip(leaf.shape, tuple(spec)):
            axes = (
                () if entry is None
                else (entry,) if isinstance(entry, str) else tuple(entry)
            )
            prod = 1
            for a in axes:
                prod *= MESH.shape[a]
            assert size % prod == 0, (arch, leaf.shape, spec)


def test_dp_axes_for_fallbacks():
    assert sh.dp_axes_for(256, MESH3) == ("pod", "data")
    assert sh.dp_axes_for(16, MESH3) == ("data",)
    assert sh.dp_axes_for(1, MESH3) == ()
    assert sh.dp_axes_for(512, MESH3, dp_only=True) == ("pod", "data", "model")
    assert sh.dp_axes_for(256, MESH, dp_only=True) == ("data", "model")
    assert sh.dp_axes_for(128, MESH, dp_only=True) == ("data",)


def test_cache_specs_decode_vs_long(arch="minitron-8b"):
    cfg = get_config(arch)
    caches = jax.eval_shape(lambda: M.init_caches(cfg, 128, 1024))
    specs = sh.cache_specs(caches, cfg, MESH, 128)
    kv = specs[0]["k"]
    assert kv[1] == "data"           # batch takes DP
    # B=1: batch axes move to the cache seq dim
    caches1 = jax.eval_shape(lambda: M.init_caches(cfg, 1, 4096))
    specs1 = sh.cache_specs(caches1, cfg, MESH, 1)
    kv1 = specs1[0]["k"]
    assert kv1[1] is None and kv1[2] == "data"
