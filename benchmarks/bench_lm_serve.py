"""LM-side integration benchmark: serving throughput with and without the
active-search kNN-LM head (smoke-scale model on CPU — the datastore search
cost is the quantity of interest; the LM is constant between the two rows)."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Csv
from repro.configs import get_smoke
from repro.core import knn_lm
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Engine, ServeConfig, build_datastore_from_model
from repro.models import model as M


def main(datastore_sizes=(4096, 65_536)) -> None:
    cfg = get_smoke("internlm2-1.8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(8, 32), dtype=np.int32)
    csv = Csv("mode,datastore_n,decode_tok_per_s")

    engine = Engine(cfg, params, mesh, ServeConfig(max_new_tokens=16))
    engine.generate(prompts)  # warm
    engine.stats = {"prefill_s": 0, "decode_s": 0, "tokens": 0}
    engine.generate(prompts)
    csv.row("lm_only", 0, f"{engine.stats['tokens']/engine.stats['decode_s']:.1f}")

    knn_cfg = knn_lm.KNNLMConfig(k=8)
    for n in datastore_sizes:
        corpus = rng.integers(0, cfg.vocab_size, size=(n // 64, 65), dtype=np.int32)
        store = build_datastore_from_model(cfg, params, corpus, knn_cfg)
        eng = Engine(cfg, params, mesh, ServeConfig(max_new_tokens=16, knn=knn_cfg),
                     datastore=store)
        eng.generate(prompts)  # warm
        eng.stats = {"prefill_s": 0, "decode_s": 0, "tokens": 0}
        eng.generate(prompts)
        csv.row("knn_lm_active_search", store.n_points,
                f"{eng.stats['tokens']/eng.stats['decode_s']:.1f}")
    return csv


if __name__ == "__main__":
    main()
