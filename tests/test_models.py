"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + finiteness asserts, and prefill/decode consistency vs the training
forward (teacher forcing) — the strongest cheap correctness check we have."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke
from repro.models import model as M
from repro.optim import adamw

pytestmark = pytest.mark.slow  # full model/system drills; fast tier skips

def _batch(cfg, rng, b=2, s=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, s)), jnp.int32),
    }
    if cfg.frontend == "audio":
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32
        )
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch, rng, key):
    cfg = get_smoke(arch)
    params = M.init_params(key, cfg)
    b, s = 2, 32
    batch = _batch(cfg, rng, b, s)
    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_runs_and_improves(arch, rng, key):
    """Two AdamW steps on one repeated batch must reduce the loss."""
    cfg = get_smoke(arch)
    params = M.init_params(key, cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    opt = adamw.init(params)
    batch = _batch(cfg, rng, 2, 16)

    @jax.jit
    def step(params, opt):
        (loss, _), grads = jax.value_and_grad(M.loss_fn, has_aux=True)(
            params, cfg, batch
        )
        params, opt, _ = adamw.update(opt_cfg, grads, opt, params)
        return params, opt, loss

    losses = []
    for _ in range(3):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_matches_forward(arch, rng, key):
    """Teacher-forced decode after prefill must reproduce forward() logits."""
    cfg = get_smoke(arch)
    if cfg.frontend == "audio":
        pytest.skip("audio frontend feeds embeddings, not tokens")
    params = M.init_params(key, cfg)
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    tokens = batch["tokens"]

    full_logits, _ = M.forward(params, cfg, batch)

    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, : s - 2]
    logits_p, caches, _ = M.prefill(params, cfg, pre_batch, cache_len=s)
    # decode the (s-2)-th token -> logits for position s-2
    tok = tokens[:, s - 2]
    logits_d, caches, _ = M.decode_step(
        params, cfg, caches, tok, jnp.int32(s - 2)
    )
    want_p = full_logits[:, s - 3, :].astype(jnp.float32)
    want_d = full_logits[:, s - 2, :].astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(logits_p.astype(jnp.float32)), np.asarray(want_p),
        rtol=0.15, atol=0.15,
    )
    np.testing.assert_allclose(
        np.asarray(logits_d.astype(jnp.float32)), np.asarray(want_d),
        rtol=0.15, atol=0.15,
    )
    # top-1 agreement (bf16 tolerant)
    agree = np.mean(
        np.asarray(jnp.argmax(logits_d, -1)) == np.asarray(jnp.argmax(want_d, -1))
    )
    assert agree >= 0.5


@pytest.mark.parametrize("arch", ["minitron-8b", "jamba-v0.1-52b", "xlstm-125m",
                                  "qwen2-moe-a2.7b"])
def test_decode_cache_structure_matches_prefill(arch, rng, key):
    cfg = get_smoke(arch)
    params = M.init_params(key, cfg)
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    _, caches_p, _ = M.prefill(params, cfg, batch, cache_len=s)
    caches_i = M.init_caches(cfg, b, s)
    t1 = jax.tree.map(lambda a: (a.shape, str(a.dtype)), caches_p)
    t2 = jax.tree.map(lambda a: (a.shape, str(a.dtype)), caches_i)
    assert jax.tree_util.tree_structure(t1) == jax.tree_util.tree_structure(t2)
    assert jax.tree.leaves(t1) == jax.tree.leaves(t2)


def test_unrolled_matches_scanned(key):
    """scan_layers=False computes the same function (FLOP-accounting probe).

    Uses a LOCAL generator, not the shared session `rng`: the bf16
    scan-vs-unroll comparison sits near its tolerance, so the batch must
    not depend on how many draws earlier-collected tests consumed (adding
    a test file used to flip this test's data and its outcome)."""
    import dataclasses
    cfg = get_smoke("internlm2-1.8b")
    params = M.init_params(key, cfg)
    batch = _batch(cfg, np.random.default_rng(7), 2, 16)
    l1, _ = M.forward(params, cfg, batch)
    cfg2 = dataclasses.replace(
        cfg, policy=dataclasses.replace(cfg.policy, scan_layers=False)
    )
    l2, _ = M.forward(params, cfg2, batch)
    # bf16 activations: scan/unroll reassociate sums -> ~0.04 logit jitter
    np.testing.assert_allclose(
        np.asarray(l1.astype(jnp.float32)), np.asarray(l2.astype(jnp.float32)),
        atol=0.08,
    )
    agree = np.mean(np.asarray(jnp.argmax(l1, -1) == jnp.argmax(l2, -1)))
    assert agree > 0.95


def test_param_count_close_to_reference():
    """6ND accounting: param_count() should be within 20% of actual leaves."""
    for arch in ARCH_NAMES:
        cfg = get_smoke(arch)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.2, (arch, est, actual)
