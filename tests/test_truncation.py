"""Property test for the SearchResult.truncated contract (window_spans /
row_cap truncation).

`truncated` must be raised EXACTLY when candidates were dropped before the
re-rank, i.e. when

  (a) the final Eq.-1 circle exceeds the candidate window
      (2 r + 1 > cfg.window), or
  (b) any window row's CSR span holds more than row_cap points (the gather
      keeps only the first row_cap records of each row).

The expectation is recomputed here in pure numpy straight from the CSR
offsets — an oracle independent of `active_search.window_spans` — and
checked on the jnp reference and BOTH pallas candidate pipelines (fused
csr_candidate_topk and the gather baseline), for clustered data (row
overflow without window overrun), spread data (neither), and grid-corner
queries (clamped windows on both axes).
"""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as hst

from repro import api
from repro.core import active_search as act
from repro.core.grid import GridConfig, build_index
from repro.core.projection import identity_projection

CFG = GridConfig(grid_size=64, tile=8, window=8, row_cap=4, r0=4,
                 k_slack=2.0)
N, B, K = 256, 8, 3


def _expected_row_overflow(index, cfg, q_grid) -> np.ndarray:
    """any(end - start > row_cap) per query, straight from the offsets."""
    g, w = cfg.padded_size, cfg.window
    offs = np.asarray(index.offsets)
    qg = np.asarray(q_grid)
    cx = np.floor(qg[:, 0]).astype(np.int64)
    cy = np.floor(qg[:, 1]).astype(np.int64)
    x0 = np.clip(cx - w // 2, 0, g - w)
    y0 = np.clip(cy - w // 2, 0, g - w)
    rows = x0[:, None] + np.arange(w)                    # (B, w)
    start = offs[rows * g + y0[:, None]]
    end = offs[rows * g + (y0[:, None] + w)]
    return (end - start > cfg.row_cap).any(axis=1)


@settings(max_examples=8, deadline=None)
@given(
    seed=hst.integers(0, 2**31 - 1),
    spread=hst.sampled_from([0.02, 0.3, 1.5]),
)
def test_truncated_iff_window_overrun_or_row_overflow(seed, spread):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.normal(size=(N, 2)) * spread, jnp.float32)
    idx = build_index(pts, CFG, identity_projection(pts))
    s = api.ActiveSearcher.from_index(idx, CFG)

    lo = float(jnp.min(pts)) - 0.5
    hi = float(jnp.max(pts)) + 0.5
    corners = np.asarray([[lo, lo], [hi, hi], [lo, hi], [hi, lo]])
    q = jnp.asarray(
        np.concatenate([corners, rng.normal(size=(B - 4, 2)) * spread]),
        jnp.float32,
    )
    from repro.core import projection as proj_lib

    q_grid = proj_lib.to_grid_coords(idx.proj, q, CFG.grid_size)
    overflow = _expected_row_overflow(idx, CFG, q_grid)

    results = {
        name: s.with_plan(backend=name).search(q, K)
        for name in ("jnp", "pallas", "pallas_gather")
    }
    ref = results["jnp"]
    window_overrun = 2 * np.asarray(ref.radius) + 1 > CFG.window
    expected = window_overrun | overflow
    for name, res in results.items():
        np.testing.assert_array_equal(
            np.asarray(res.truncated), expected, err_msg=name
        )
        np.testing.assert_array_equal(
            np.asarray(res.radius), np.asarray(ref.radius), err_msg=name
        )
