"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh) cell, per the assignment:

    compute    = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_chip / HBM_bw_per_chip
    collective = collective_bytes_per_chip / link_bw

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis() (the SPMD-partitioned
per-device program).  collective_bytes is parsed from the optimized HLO text:
the summed OUTPUT operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (bytes landing on each device — the
receive-side traffic a link must carry).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %ag = (bf16[8,128]{1,0}, f32[4]{0}) all-gather(...)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{\s*$")
# fusion bodies and reducer lambdas are not materialized; while bodies ARE
# (and appear once — fine for the unrolled probes, which have no whiles)
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_NO_WRITE = (
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "token",
)


def fused_bytes(hlo_text: str, shape_pred=None) -> int:
    """HBM bytes WRITTEN by materialized buffers: sum of output shapes of ops
    in every computation EXCEPT fusion bodies (fusion internals live in
    registers/VMEM).  cost_analysis()'s 'bytes accessed' counts every op as
    if unfused — a ~10-20x overestimate of real HBM traffic on a fused
    executable; this is the fused-buffer lower-ish bound.  Exact for the
    unrolled cost probes (no while loops).

    shape_pred(dims: list[int]) optionally restricts the count to matching
    buffers (used to attribute bytes to e.g. attention-score shapes)."""
    # map computation -> op output bytes; find fusion-called computations
    comps: dict[str, int] = {}
    fusion_bodies: set[str] = set()
    current = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_HEAD.match(stripped)
        if m and stripped.endswith("{"):
            current = m.group(2)
            comps.setdefault(current, 0)
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None or "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1].strip()
        op_m = re.match(r"(\([^)]*\)|\S+)\s+([\w\-]+)", rhs)
        if not op_m:
            continue
        shape_str, opname = op_m.group(1), op_m.group(2)
        # any op's calls=/to_apply= computation is inlined, not materialized
        for c in _CALLS_RE.findall(stripped):
            fusion_bodies.add(c)
        if opname in _NO_WRITE:
            continue
        if shape_pred is not None:
            sm = _SHAPE_RE.search(shape_str)
            if not sm:
                continue
            dims = [int(d) for d in sm.group(2).split(",") if d]
            if not shape_pred(dims):
                continue
        comps[current] += _shape_bytes(shape_str)
    return sum(b for name, b in comps.items() if name not in fusion_bodies)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind OUTPUT bytes of every collective in the optimized HLO.

    `-done` ops re-state the tuple shape of their `-start`; counting only
    `-start` (and un-suffixed sync forms) avoids double counting."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        out[m.group(2)] += _shape_bytes(m.group(1))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float               # per-chip HLO FLOPs
    hbm_bytes: float           # per-chip HLO bytes accessed (UNFUSED upper bound)
    coll_bytes: float          # per-chip collective bytes (receive side)
    coll_by_kind: dict[str, int]
    chips: int
    fused_hbm_bytes: float = 0.0   # materialized-buffer writes (fused estimate)
    compute_s: float = 0.0
    memory_s: float = 0.0          # from fused bytes when available
    memory_upper_s: float = 0.0    # from unfused bytes
    collective_s: float = 0.0
    bottleneck: str = ""

    def finalize(self) -> "Roofline":
        self.compute_s = self.flops / PEAK_FLOPS
        self.memory_upper_s = self.hbm_bytes / HBM_BW
        mem_bytes = self.fused_hbm_bytes or self.hbm_bytes
        self.memory_s = mem_bytes / HBM_BW
        self.collective_s = self.coll_bytes / LINK_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.bottleneck = max(terms, key=terms.get)
        return self

    @property
    def step_time_s(self) -> float:
        """Roofline-model step time: max of the three terms (full overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "fused_hbm_bytes_per_chip": self.fused_hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_upper_s": self.memory_upper_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
        }


def from_compiled(compiled, chips: int, hlo_text: str | None = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(sum(coll.values())),
        coll_by_kind=coll,
        chips=chips,
        fused_hbm_bytes=float(fused_bytes(text)),
    ).finalize()


def model_flops(cfg, shape, kind: str) -> float:
    """Useful-work FLOPs: 6 * N_active * tokens (the standard 6ND estimate)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens   # forward only
    # decode: one token per sequence; attention reads the cache but 2ND
    # stays the useful-FLOPs yardstick
    return 2.0 * n_active * shape.global_batch


def memory_analysis_dict(compiled) -> dict[str, float] | None:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    return out or None
