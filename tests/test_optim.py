"""AdamW + gradient compression (error feedback) behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as hst

from repro.optim import adamw, compression


def test_adamw_minimizes_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, _ = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 0.2


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8], rtol=1e-5)


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(adamw.schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(adamw.schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    end = float(adamw.schedule(cfg, jnp.int32(100)))
    assert abs(end - 0.1) < 1e-3


def test_decay_mask_excludes_norms():
    cfg = adamw.AdamWConfig(lr=0.0, weight_decay=1.0, warmup_steps=0)
    params = {"w": jnp.ones((2, 2)), "norm1": jnp.ones((2,))}
    state = adamw.init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw.update(cfg, zero_g, state, params)
    # lr=0 -> nothing moves regardless of decay; use lr>0 to see decay applied
    cfg2 = adamw.AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0, eps=1.0)
    new2, _, _ = adamw.update(cfg2, zero_g, adamw.init(params), params)
    assert float(new2["w"][0, 0]) < 1.0           # decayed
    assert float(new2["norm1"][0]) == 1.0          # masked


def test_compression_error_feedback_unbiased():
    """Sum of dequantized grads ≈ sum of true grads (error feedback)."""
    rng = np.random.default_rng(0)
    err = jnp.zeros((64,))
    total_true = np.zeros((64,))
    total_hat = np.zeros((64,))
    for i in range(50):
        g = jnp.asarray(rng.normal(size=64) * (1 + i % 5), jnp.float32)
        g_hat, err = compression.compress_leaf(g, err)
        total_true += np.asarray(g)
        total_hat += np.asarray(g_hat)
    # residual carries over, so cumulative sums track within one quant step
    scale = np.abs(total_true).max() / 127
    np.testing.assert_allclose(total_hat, total_true, atol=10 * scale)


@settings(max_examples=20, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1))
def test_compression_residual_bounded(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=32), jnp.float32)
    g_hat, err = compression.compress_leaf(g, jnp.zeros((32,)))
    # quantization error bounded by half a quant step
    step = float(jnp.max(jnp.abs(g))) / 127
    assert float(jnp.abs(err).max()) <= step * 0.51 + 1e-6


def test_compressed_training_tracks_uncompressed():
    """Quadratic descent with int8+EF grads stays close to exact descent."""
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0)
    p1 = {"x": jnp.asarray([4.0, -2.0, 1.0])}
    p2 = jax.tree.map(jnp.copy, p1)
    s1, s2 = adamw.init(p1), adamw.init(p2)
    err = compression.init_error(p1)
    for _ in range(100):
        g1 = {"x": 2 * p1["x"]}
        p1, s1, _ = adamw.update(cfg, g1, s1, p1)
        g2 = {"x": 2 * p2["x"]}
        g2c, err = compression.compress_grads(g2, err)
        p2, s2, _ = adamw.update(cfg, g2c, s2, p2)
    np.testing.assert_allclose(
        np.asarray(p1["x"]), np.asarray(p2["x"]), atol=0.05
    )
