"""Pallas kernels (interpret=True on CPU) vs the pure-jnp ref.py oracles.
Shape/dtype sweeps per kernel, as the assignment requires."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as hst

from repro.kernels import ops, ref


# ------------------------------------------------------------ tile_count ----


# CONTRACT: kernel == ref when the circle fits the T-cell window, i.e.
# r <= scale * (tile/2 - 1.5).  pyramid.level_for_radius guarantees this.
def _rmax(tile, scale):
    return scale * (tile / 2 - 1.5)


@pytest.mark.parametrize("s,tile,c", [(32, 8, 1), (64, 16, 3), (128, 16, 4), (64, 8, 8)])
@pytest.mark.parametrize("scale", [1, 2, 4])
@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_tile_count_sweep(rng, s, tile, c, scale, metric):
    level = jnp.asarray(rng.integers(0, 5, size=(s, s, c)), jnp.int32)
    b = 9
    q = jnp.asarray(rng.uniform(0, s * scale, size=(b, 2)), jnp.float32)
    r = jnp.asarray(rng.uniform(0.5, _rmax(tile, scale), size=(b,)), jnp.float32)
    got = ops.tile_count(level, q, r, scale, tile, metric=metric, interpret=True)
    want = ref.tile_count(level, q, r, scale, tile, metric=metric)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tile_count_edges(rng):
    """Queries at corners/borders where the window clamps."""
    s, tile = 32, 8
    level = jnp.asarray(rng.integers(0, 3, size=(s, s, 2)), jnp.int32)
    q = jnp.asarray([[0.0, 0.0], [31.9, 31.9], [0.0, 31.9], [16.0, 0.0]], jnp.float32)
    r = jnp.asarray([2.0, 2.5, 1.5, 2.4], jnp.float32)
    got = ops.tile_count(level, q, r, 1, tile, interpret=True)
    want = ref.tile_count(level, q, r, 1, tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tile_count_beyond_window_matches_ref():
    """Past the contract radius the kernel masks to the clamped T-window, so
    it stays bit-identical to ref (which truncates at its T-window) instead
    of overcounting from its 2Tx2T block cover."""
    rng = np.random.default_rng(1)
    s, tile = 32, 8
    level = jnp.asarray(rng.integers(0, 3, size=(s, s, 1)), jnp.int32)
    q = jnp.asarray(rng.uniform(0, s, size=(6, 2)), jnp.float32)
    r = jnp.asarray(rng.uniform(4.0, 7.5, size=(6,)), jnp.float32)
    got = np.asarray(ops.tile_count(level, q, r, 1, tile, interpret=True))
    want = np.asarray(ref.tile_count(level, q, r, 1, tile))
    np.testing.assert_array_equal(got, want)


def test_tile_count_window_parity_grid_edge():
    """The headline regime for the window-parity fix: queries at grid
    corners/borders with radii far past the contract, where the clamped
    window and the circle disagree the most."""
    rng = np.random.default_rng(2)
    s, tile = 32, 8
    level = jnp.asarray(rng.integers(0, 4, size=(s, s, 2)), jnp.int32)
    q = jnp.asarray(
        [[0.0, 0.0], [31.9, 31.9], [0.0, 31.9], [31.9, 0.0], [0.5, 16.0]],
        jnp.float32,
    )
    r = jnp.asarray([10.0, 20.0, 31.0, 8.0, 15.0], jnp.float32)
    got = np.asarray(ops.tile_count(level, q, r, 1, tile, interpret=True))
    want = np.asarray(ref.tile_count(level, q, r, 1, tile))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1))
def test_tile_count_property(seed):
    rng = np.random.default_rng(seed)
    s = int(rng.choice([16, 32, 64]))
    tile = int(rng.choice([8, 16]))
    tile = min(tile, s)
    c = int(rng.integers(1, 5))
    scale = int(rng.choice([1, 2]))
    level = jnp.asarray(rng.integers(0, 4, size=(s, s, c)), jnp.int32)
    b = int(rng.integers(1, 6))
    q = jnp.asarray(rng.uniform(0, s * scale, size=(b, 2)), jnp.float32)
    r = jnp.asarray(rng.uniform(0.5, _rmax(tile, scale), size=(b,)), jnp.float32)
    got = ops.tile_count(level, q, r, scale, tile, interpret=True)
    want = ref.tile_count(level, q, r, scale, tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------- tile_count_multilevel ----


def _pyramid_fixture(rng, grid=64, tile=8, c=2):
    from repro.core.grid import GridConfig, build_index
    from repro.core.projection import identity_projection

    pts = jnp.asarray(rng.normal(size=(800, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, c, size=800), jnp.int32)
    cfg = GridConfig(grid_size=grid, tile=tile, n_classes=c, r0=8)
    idx = build_index(pts, cfg, identity_projection(pts), labels=labels)
    return cfg, idx


@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_tile_count_multilevel_matches_ref(rng, metric):
    """One level-scheduled pallas_call == the stacked per-level select, for
    radii spanning every pyramid level."""
    from repro.core import pyramid as pyr

    cfg, idx = _pyramid_fixture(rng)
    b = 16
    q = jnp.asarray(rng.uniform(0, cfg.padded_size, size=(b, 2)), jnp.float32)
    r = jnp.asarray(rng.uniform(0.5, cfg.max_radius, size=(b,)), jnp.float32)
    lv = pyr.level_for_radius(r, cfg)
    got = ops.tile_count_multilevel(
        idx.pyr_tiles, q, r, lv, cfg.tile, cfg.level_nblks, metric=metric,
        interpret=True,
    )
    want = ref.tile_count_multilevel(idx.pyramid, q, r, lv, cfg.tile, metric=metric)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tile_count_multilevel_forced_levels(rng):
    """Level is an INPUT, not derived: forcing every query to each level in
    turn must reproduce that level's single-level kernel — including levels
    whose window the circle overruns (window parity)."""
    cfg, idx = _pyramid_fixture(rng)
    b = 8
    q = jnp.asarray(rng.uniform(0, cfg.padded_size, size=(b, 2)), jnp.float32)
    r = jnp.asarray(rng.uniform(0.5, cfg.max_radius / 2, size=(b,)), jnp.float32)
    for lv in range(cfg.levels):
        levels = jnp.full((b,), lv, jnp.int32)
        got = ops.tile_count_multilevel(
            idx.pyr_tiles, q, r, levels, cfg.tile, cfg.level_nblks,
            interpret=True,
        )
        want = ref.tile_count(idx.pyramid[lv], q, r, 1 << lv, cfg.tile)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=f"level {lv}"
        )


def test_tile_count_multilevel_max_radius_top_level(rng):
    """r == max_radius clamps level selection at levels-1; the top tile IS
    the whole level there, so the count must equal the total mass inside the
    circle of the full grid."""
    from repro.core import pyramid as pyr

    cfg, idx = _pyramid_fixture(rng)
    b = 5
    q = jnp.asarray(rng.uniform(0, cfg.padded_size, size=(b, 2)), jnp.float32)
    r = jnp.full((b,), float(cfg.max_radius), jnp.float32)
    lv = pyr.level_for_radius(r, cfg)
    assert int(lv[0]) == cfg.levels - 1
    got = ops.tile_count_multilevel(
        idx.pyr_tiles, q, r, lv, cfg.tile, cfg.level_nblks, interpret=True
    )
    want = ref.tile_count_multilevel(idx.pyramid, q, r, lv, cfg.tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tile_count_multilevel_bad_layout_raises(rng):
    cfg, idx = _pyramid_fixture(rng)
    with pytest.raises(ValueError, match="tiles shape"):
        ops.tile_count_multilevel(
            idx.pyr_tiles[:-1], jnp.zeros((1, 2), jnp.float32),
            jnp.ones((1,), jnp.float32), jnp.zeros((1,), jnp.int32),
            cfg.tile, cfg.level_nblks, interpret=True,
        )


# -------------------------------------------------------- candidate_topk ----


@pytest.mark.parametrize("b,c,d,k", [(4, 16, 8, 3), (2, 64, 32, 11), (1, 128, 300, 16), (8, 32, 512, 5)])
@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_candidate_topk_sweep(rng, b, c, d, k, metric):
    cand = jnp.asarray(rng.normal(size=(b, c, d)), jnp.float32)
    valid = jnp.asarray(rng.uniform(size=(b, c)) > 0.3)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    gd, gi = ops.candidate_topk(cand, valid, q, k, metric=metric, d_chunk=128,
                                interpret=True)
    wd, wi = ref.candidate_topk(cand, valid, q, k, metric=metric)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-5, atol=1e-5)
    # indices may differ on exact ties; check distances of chosen candidates
    for i in range(b):
        for j in range(k):
            if wi[i, j] >= 0:
                assert gi[i, j] >= 0


def test_candidate_topk_all_invalid(rng):
    cand = jnp.asarray(rng.normal(size=(2, 8, 4)), jnp.float32)
    valid = jnp.zeros((2, 8), bool)
    q = jnp.zeros((2, 4), jnp.float32)
    gd, gi = ops.candidate_topk(cand, valid, q, 3, interpret=True)
    assert bool(jnp.all(jnp.isinf(gd)))
    assert bool(jnp.all(gi == -1))


def test_candidate_topk_c_smaller_than_k(rng):
    """k exceeds the candidate count: the first C slots match the k=C oracle,
    the rest pad with +inf / -1 (the batched backend relies on this)."""
    b, c, d, k = 3, 5, 6, 9
    cand = jnp.asarray(rng.normal(size=(b, c, d)), jnp.float32)
    valid = jnp.ones((b, c), bool)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    gd, gi = ops.candidate_topk(cand, valid, q, k, interpret=True)
    wd, wi = ref.candidate_topk(cand, valid, q, c)  # oracle at k=C
    np.testing.assert_allclose(np.asarray(gd[:, :c]), np.asarray(wd),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(gi[:, :c]), np.asarray(wi))
    assert bool(jnp.all(jnp.isinf(gd[:, c:])))
    assert bool(jnp.all(gi[:, c:] == -1))


def test_candidate_topk_partially_invalid_fewer_than_k(rng):
    """Fewer VALID candidates than k: invalid slots never leak into the top-k."""
    b, c, d, k = 2, 16, 4, 8
    cand = jnp.asarray(rng.normal(size=(b, c, d)), jnp.float32)
    valid = jnp.zeros((b, c), bool).at[:, :3].set(True)  # only 3 valid
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    gd, gi = ops.candidate_topk(cand, valid, q, k, interpret=True)
    wd, wi = ref.candidate_topk(cand, valid, q, k)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    assert bool(jnp.all(gi[:, 3:] == -1))


# ---------------------------------------------------- csr_candidate_topk ----


def _csr_fixture(rng, n=600, d=6, b=5, w=7, rcap=16):
    store = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    starts = jnp.asarray(rng.integers(0, n - 4, size=(b, w)), jnp.int32)
    # spans from empty through overflowing (end - start > rcap)
    ends = starts + jnp.asarray(
        rng.integers(0, rcap + 6, size=(b, w)), jnp.int32
    )
    ends = jnp.minimum(ends, n)
    return store, starts, ends, q


@pytest.mark.parametrize("metric", ["l2", "l1"])
@pytest.mark.parametrize("k", [1, 5, 16])
def test_csr_candidate_topk_sweep(rng, metric, k):
    """The fused gather+distance+top-k kernel == its dense-gather oracle
    BIT-FOR-BIT (global CSR indices included), spans spanning empty rows,
    partial rows, and row_cap-overflowing rows."""
    store, starts, ends, q = _csr_fixture(rng)
    gd, gi = ops.csr_candidate_topk(
        store, starts, ends, q, k, store.shape[0], 16, metric=metric,
        interpret=True,
    )
    wd, wi = ref.csr_candidate_topk(
        store, starts, ends, q, k, store.shape[0], 16, metric=metric
    )
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_csr_candidate_topk_paper_mode():
    """center_cells + radii reproduce mode='paper': rank floor(coords)+0.5
    cell centers and mask candidates outside the Eq.-1 circle.

    Local generator, not the session rng: a cell center can land within
    1 ulp of the circle radius, where the kernel's and the oracle's
    inclusion masks may flip independently — the drawn geometry must not
    depend on how many tests consumed the session stream before this one."""
    local = np.random.default_rng(7)
    store, starts, ends, _ = _csr_fixture(local, d=2)
    store = store * 8.0  # spread across cells so floor() matters
    q = jnp.asarray(local.uniform(-16, 16, size=(5, 2)), jnp.float32)
    radii = jnp.asarray(local.uniform(1.0, 12.0, size=(5,)), jnp.float32)
    gd, gi = ops.csr_candidate_topk(
        store, starts, ends, q, 4, store.shape[0], 16, radii=radii,
        center_cells=True, interpret=True,
    )
    wd, wi = ref.csr_candidate_topk(
        store, starts, ends, q, 4, store.shape[0], 16, radii=radii,
        center_cells=True,
    )
    # distances allclose / indices exact, like the drawn-d sweep: the two
    # reductions can sit 1 ulp apart (the pinned inter-kernel caveat)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_csr_candidate_topk_live_boundary(rng):
    """Spans that reach past the live CSR length n (store rows >= n are
    padding) never surface a padded row."""
    n_live, n_pad = 40, 64
    store = jnp.asarray(rng.normal(size=(n_pad, 4)), jnp.float32)
    starts = jnp.asarray([[30, 38, 0]], jnp.int32)
    ends = jnp.asarray([[50, 64, 8]], jnp.int32)  # overrun the live region
    q = jnp.zeros((1, 4), jnp.float32)
    gd, gi = ops.csr_candidate_topk(
        store, starts, ends, q, 32, n_live, 16, interpret=True
    )
    wd, wi = ref.csr_candidate_topk(store, starts, ends, q, 32, n_live, 16)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    live = np.asarray(gi)[np.asarray(gi) >= 0]
    assert (live < n_live).all()


def test_csr_candidate_topk_k_exceeds_window(rng):
    """k > w*row_cap: the streaming select pads with +inf / -1."""
    store, starts, ends, q = _csr_fixture(rng, b=2, w=2, rcap=4)
    k = 2 * 4 + 3
    gd, gi = ops.csr_candidate_topk(
        store, starts, ends, q, k, store.shape[0], 4, interpret=True
    )
    wd, wi = ref.csr_candidate_topk(store, starts, ends, q, k,
                                    store.shape[0], 4)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    assert bool(jnp.all(jnp.isinf(gd[:, -3:]))) and bool(jnp.all(gi[:, -3:] == -1))


def test_csr_candidate_topk_d_chunk_accumulation(rng):
    """An explicit d_chunk cap trades the single-sum reduction for bounded
    VMEM (documented reassociation of the float32 sums): distances stay
    allclose to the one-step oracle and the selected candidates agree."""
    store, starts, ends, q = _csr_fixture(rng, d=10)
    n, rcap, k = store.shape[0], 16, 5
    wd, wi = ref.csr_candidate_topk(store, starts, ends, q, k, n, rcap)
    for dc in (3, 4, 10, 64):
        gd, gi = ops.csr_candidate_topk(
            store, starts, ends, q, k, n, rcap, d_chunk=dc, interpret=True
        )
        np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"d_chunk={dc}")
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi),
                                      err_msg=f"d_chunk={dc}")


def test_csr_candidate_topk_matches_dense_kernel_large_d(rng):
    """The inter-KERNEL invariant behind backend parity: fused == the
    gather pipeline's dense candidate_topk BIT-for-bit — including d large
    enough (d=10 here) that both kernels' reductions drift 1 ulp from the
    big-tensor jnp oracle in the same direction."""
    store, starts, ends, q = _csr_fixture(rng, d=10)
    n, rcap, k = store.shape[0], 16, 5
    b = q.shape[0]
    gd, gi = ops.csr_candidate_topk(
        store, starts, ends, q, k, n, rcap, interpret=True
    )
    s_cl = jnp.clip(starts, 0, n - rcap)
    j = s_cl[:, :, None] + jnp.arange(rcap, dtype=jnp.int32)
    ok = (j >= starts[:, :, None]) & (j < ends[:, :, None]) & (j < n)
    flat = j.reshape(b, -1)
    dd, di = ops.candidate_topk(
        jnp.take(store, flat, axis=0), ok.reshape(b, -1), q, k,
        d_chunk=store.shape[1], interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(dd))
    gflat = jnp.where(
        di >= 0, jnp.take_along_axis(flat, jnp.maximum(di, 0), axis=1), -1
    )
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(gflat))


@settings(max_examples=10, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1))
def test_csr_candidate_topk_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(32, 400))
    d = int(rng.integers(2, 12))
    b = int(rng.integers(1, 5))
    w = int(rng.integers(1, 6))
    rcap = int(rng.choice([4, 8, 16]))
    rcap = min(rcap, n)
    k = int(rng.integers(1, 9))
    store = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    starts = jnp.asarray(rng.integers(0, n, size=(b, w)), jnp.int32)
    ends = jnp.minimum(
        starts + jnp.asarray(rng.integers(0, rcap + 4, size=(b, w)), jnp.int32),
        n,
    )
    gd, gi = ops.csr_candidate_topk(
        store, starts, ends, q, k, n, rcap, interpret=True
    )
    wd, wi = ref.csr_candidate_topk(store, starts, ends, q, k, n, rcap)
    # at larger drawn d the kernel's per-row reduction can sit 1 ulp from
    # the big-tensor oracle (see ..._matches_dense_kernel_large_d, which
    # pins the inter-kernel BIT contract); selection must still agree
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_csr_candidate_topk_store_too_small_raises(rng):
    store = jnp.zeros((4, 3), jnp.float32)
    with pytest.raises(ValueError, match="row_cap"):
        ops.csr_candidate_topk(
            store, jnp.zeros((1, 2), jnp.int32), jnp.zeros((1, 2), jnp.int32),
            jnp.zeros((1, 3), jnp.float32), 2, 4, 8, interpret=True,
        )


def test_tile_count_zero_radius(rng):
    """r=0: only a cell whose center coincides with the query could count."""
    s, tile = 32, 8
    level = jnp.asarray(rng.integers(0, 3, size=(s, s, 2)), jnp.int32)
    q = jnp.asarray([[10.5, 20.5], [3.0, 7.0]], jnp.float32)  # on/off centers
    r = jnp.zeros((2,), jnp.float32)
    got = ops.tile_count(level, q, r, 1, tile, interpret=True)
    want = ref.tile_count(level, q, r, 1, tile)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_tile_count_full_pyramid_levels(rng):
    """Every level of a real pyramid agrees with the oracle at its scale —
    the exact sweep the batched radius loop performs."""
    from repro.core.grid import GridConfig, build_index
    from repro.core.projection import identity_projection

    pts = jnp.asarray(rng.normal(size=(500, 2)), jnp.float32)
    cfg = GridConfig(grid_size=64, tile=8, r0=8)
    idx = build_index(pts, cfg, identity_projection(pts))
    q = jnp.asarray(rng.uniform(0, cfg.padded_size, size=(7, 2)), jnp.float32)
    for lv, arr in enumerate(idx.pyramid):
        scale = 1 << lv
        r = jnp.asarray(
            rng.uniform(0.5, scale * (cfg.tile / 2 - 1.5), size=(7,)), jnp.float32
        )
        got = ops.tile_count(arr, q, r, scale, cfg.tile, interpret=True)
        want = ref.tile_count(arr, q, r, scale, cfg.tile)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"level {lv}")


# ------------------------------------------------------------- brute_knn ----


@pytest.mark.parametrize("b,n,d,k", [(4, 100, 8, 5), (2, 1000, 16, 11), (128, 700, 4, 3), (1, 64, 128, 20)])
def test_brute_knn_sweep(rng, b, n, d, k):
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    gd, gi = ops.brute_knn(q, x, k, block_q=32, block_n=128, interpret=True)
    wd, wi = ref.brute_knn(q, x, k)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-4, atol=1e-4)
    # id sets agree except for ties at the k-th distance
    for i in range(b):
        inter = set(np.asarray(gi[i]).tolist()) & set(np.asarray(wi[i]).tolist())
        assert len(inter) >= k - 2


def test_brute_knn_k_bigger_than_blocks(rng):
    q = jnp.asarray(rng.normal(size=(3, 6)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(50, 6)), jnp.float32)
    gd, gi = ops.brute_knn(q, x, 7, block_q=2, block_n=16, interpret=True)
    wd, _ = ref.brute_knn(q, x, 7)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1))
def test_brute_knn_property(seed):
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 9))
    n = int(rng.integers(5, 300))
    d = int(rng.integers(2, 40))
    k = int(rng.integers(1, min(n, 12) + 1))
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    gd, _ = ops.brute_knn(q, x, k, block_q=16, block_n=64, interpret=True)
    wd, _ = ref.brute_knn(q, x, k)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-4, atol=1e-4)


# -------------------------------------------------------- flash_attention ----


@pytest.mark.parametrize("b,s,t,h,hd,causal", [
    (2, 64, 64, 4, 32, True),
    (1, 128, 128, 2, 64, True),
    (2, 32, 96, 3, 16, False),
    (1, 256, 256, 1, 128, True),
])
def test_flash_attention_sweep(rng, b, s, t, h, hd, causal):
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                              interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,
    )


@settings(max_examples=10, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1))
def test_flash_attention_property(seed):
    rng = np.random.default_rng(seed)
    bq = int(rng.choice([8, 16, 32]))
    nq = int(rng.integers(1, 5))
    nk = int(rng.integers(1, 5))
    h = int(rng.integers(1, 4))
    hd = int(rng.choice([16, 32, 64]))
    causal = bool(rng.integers(0, 2)) and nq == nk
    s, t = bq * nq, bq * nk
    q = jnp.asarray(rng.normal(size=(1, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, t, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, t, h, hd)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bq,
                              interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
