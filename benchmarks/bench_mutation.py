"""Streaming mutation vs full rebuild: the price of growing the datastore.

Measures, at serve-relevant N:

  * inserts/sec through `core.mutable.insert` (delta scatter into the CSR
    slack + every pyramid level + dirty-tile refresh) vs re-running
    `build_index` on the union — the headline `speedup_insert_vs_rebuild`;
  * the same including `snapshot()` (the O(N) sort-free merge a handle pays
    to become searchable) — `speedup_with_snapshot`;
  * post-insert queries/sec on the incrementally grown index next to the
    rebuilt one (identical results; the row records the parity check).

Results land in BENCH_mutation.json (see REPRO_BENCH_ARTIFACTS) so CI records
the mutation-path trajectory next to BENCH_kernels.json / BENCH_e2e.json.

Env knobs:
  REPRO_BENCH_QUICK=1      fewer repeats (N stays 100k: insert cost is
                           N-independent, rebuild cost is the point)
  REPRO_BENCH_ARTIFACTS=D  directory for BENCH_mutation.json (default ".")
"""

from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, timeit
from repro import api
from repro.core import mutable as mut
from repro.core.grid import build_index


def _quick() -> bool:
    return os.environ.get("REPRO_BENCH_QUICK", "0") == "1"


def main() -> None:
    rng = np.random.default_rng(0)
    n, m, b, k = 100_000, 1024, 64, 11
    repeats = 3 if _quick() else 5
    cfg = api.GridConfig(grid_size=256, tile=16, window=32, row_cap=32,
                         r0=10, k_slack=2.0)
    base_pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    new_pts = jnp.asarray(rng.normal(size=(m, 2)), jnp.float32)
    union = jnp.concatenate([base_pts, new_pts], axis=0)
    proj = api.identity_projection(union)  # shared extents: parity-comparable

    index = build_index(base_pts, cfg, proj)
    state = mut.from_index(index, cfg)
    q = jnp.asarray(rng.normal(size=(b, 2)), jnp.float32)

    # time the FULL result pytrees (jax dispatch is async; blocking on a
    # single leaf would omit the gathers/pyramid/tile work of either path)
    t_rebuild = timeit(lambda: build_index(union, cfg, proj),
                       repeats=repeats, warmup=1)
    t_insert = timeit(lambda: mut.insert(state, cfg, new_pts),
                      repeats=repeats, warmup=1)
    grown = mut.insert(state, cfg, new_pts)
    t_snapshot = timeit(lambda: mut.snapshot(grown, cfg),
                        repeats=repeats, warmup=1)

    rebuilt = build_index(union, cfg, proj)
    s_inc = api.ActiveSearcher.from_index(mut.snapshot(grown, cfg), cfg)
    s_reb = api.ActiveSearcher.from_index(rebuilt, cfg)
    t_q_inc = timeit(lambda: s_inc.search(q, k).ids, repeats=repeats, warmup=1)
    t_q_reb = timeit(lambda: s_reb.search(q, k).ids, repeats=repeats, warmup=1)
    parity = bool(np.array_equal(np.asarray(s_inc.search(q, k).ids),
                                 np.asarray(s_reb.search(q, k).ids)))

    speedup = t_rebuild / t_insert
    speedup_snap = t_rebuild / (t_insert + t_snapshot)
    csv = Csv("metric,value")
    csv.row("n_points", n)
    csv.row("insert_batch", m)
    csv.row("rebuild_s", f"{t_rebuild:.4f}")
    csv.row("insert_s", f"{t_insert:.4f}")
    csv.row("snapshot_s", f"{t_snapshot:.4f}")
    csv.row("inserts_per_s", f"{m / t_insert:.0f}")
    csv.row("speedup_insert_vs_rebuild", f"{speedup:.1f}x")
    csv.row("speedup_with_snapshot", f"{speedup_snap:.1f}x")
    csv.row("post_insert_qps", f"{b / t_q_inc:.1f}")
    csv.row("post_rebuild_qps", f"{b / t_q_reb:.1f}")
    csv.row("parity_incremental_vs_rebuild", parity)

    results = {
        "schema": 1, "timestamp": time.time(), "quick": _quick(),
        "n": n, "insert_batch": m, "batch": b, "k": k,
        "rebuild_s": t_rebuild, "insert_s": t_insert, "snapshot_s": t_snapshot,
        "inserts_per_s": m / t_insert,
        "speedup_insert_vs_rebuild": speedup,
        "speedup_with_snapshot": speedup_snap,
        "post_insert_qps": b / t_q_inc, "post_rebuild_qps": b / t_q_reb,
        "parity_incremental_vs_rebuild": parity,
    }
    art_dir = os.environ.get("REPRO_BENCH_ARTIFACTS", ".")
    path = os.path.join(art_dir, "BENCH_mutation.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_mutation] wrote {path}", flush=True)


if __name__ == "__main__":
    main()
