"""Jitted step factories: train_step / prefill_step / serve_step with explicit
in/out shardings, ready for .lower().compile() (dry-run) or real execution.

All factories take the mesh and return (jitted_fn, input ShapeDtypeStructs) so
the dry-run and the real drivers share one code path.  State args are donated.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import shapes as shp
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import adamw, compression
from repro.parallel import axes
from repro.parallel import sharding as sh


@dataclasses.dataclass(frozen=True)
class StepConfig:
    accum: int = 1                 # gradient-accumulation microbatches
    compress_grads: bool = False   # int8 error-feedback gradient compression
    aux_weight: float = 0.01
    # cast params to bf16 ONCE per step before the layer scan: weight
    # all-gathers and HBM reads move half the bytes; fp32 masters stay in the
    # optimizer (EXPERIMENTS.md, hillclimb cell b).  Matrices only.
    bf16_compute_copy: bool = True


def _compute_copy(params):
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if (p.dtype == jnp.float32 and p.ndim >= 2) else p,
        params,
    )


def _ns(mesh: Mesh, tree_specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ----------------------------------------------------------------- state ----


def train_state_shapes(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, step_cfg: StepConfig):
    """abstract (ShapeDtypeStruct) train state — no allocation."""

    def build():
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        state = {
            "params": params,
            "opt": adamw.init(params),
            "step": jnp.int32(0),
        }
        if step_cfg.compress_grads:
            state["err"] = compression.init_error(params)
        return state

    return jax.eval_shape(build)


def train_state_specs(state: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    pspecs = sh.param_specs(state["params"], cfg, mesh)
    specs = {
        "params": pspecs,
        "opt": adamw.OptState(mu=pspecs, nu=pspecs, count=P()),
        "step": P(),
    }
    if "err" in state:
        specs["err"] = pspecs
    return specs


def init_train_state(
    key, cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, step_cfg: StepConfig, mesh: Mesh
) -> Any:
    """Real, sharded initialization (used by train.py; jitted so each device
    materializes only its own param shards)."""
    abstract = train_state_shapes(cfg, opt_cfg, step_cfg)
    specs = train_state_specs(abstract, cfg, mesh)

    def build(k):
        params = M.init_params(k, cfg)
        state = {"params": params, "opt": adamw.init(params), "step": jnp.int32(0)}
        if step_cfg.compress_grads:
            state["err"] = compression.init_error(params)
        return state

    with mesh:
        return jax.jit(build, out_shardings=_ns(mesh, specs))(key)


# ------------------------------------------------------------- train step ----


def _microbatch(batch: dict, accum: int) -> dict:
    def split(leaf):
        b = leaf.shape[0]
        assert b % accum == 0, f"batch {b} % accum {accum}"
        return leaf.reshape(accum, b // accum, *leaf.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    mesh: Mesh,
    step_cfg: StepConfig = StepConfig(),
) -> Callable:
    """(state, batch) -> (state, metrics), jitted with explicit shardings."""

    def grads_of(params, batch):
        if step_cfg.bf16_compute_copy:
            def loss_of(p):
                return M.loss_fn(_compute_copy(p), cfg, batch, step_cfg.aux_weight)
        else:
            def loss_of(p):
                return M.loss_fn(p, cfg, batch, step_cfg.aux_weight)
        (loss, parts), grads = jax.value_and_grad(loss_of, has_aux=True)(params)
        return grads, {"loss": loss, **parts}

    accum = step_cfg.accum if step_cfg.accum > 1 else max(cfg.policy.accum, 1)

    def train_step(state, batch):
        b = jax.tree.leaves(batch)[0].shape[0]
        rules = axes.axis_rules(mesh, axes.default_rules(cfg, mesh, b))
        with rules:
            return _train_step_body(state, batch)

    def _train_step_body(state, batch):
        params = state["params"]
        if accum == 1:
            grads, metrics = grads_of(params, batch)
        else:
            micro = _microbatch(batch, accum)

            def body(carry, mb):
                acc, _ = carry
                g, met = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g
                )
                return (acc, met), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            dummy = {
                "loss": jnp.float32(0), "nll": jnp.float32(0), "aux": jnp.float32(0)
            }
            (gsum, metrics), _ = lax.scan(body, (zeros, dummy), micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)

        new_state = dict(state)
        if step_cfg.compress_grads:
            grads, new_err = compression.compress_grads(grads, state["err"])
            new_state["err"] = new_err
        params, opt, opt_metrics = adamw.update(opt_cfg, grads, state["opt"], params)
        new_state["params"] = params
        new_state["opt"] = opt
        new_state["step"] = state["step"] + 1
        return new_state, {**metrics, **opt_metrics}

    abstract = train_state_shapes(cfg, opt_cfg, step_cfg)
    state_sh = _ns(mesh, train_state_specs(abstract, cfg, mesh))
    shape = None  # batch sharding is shape-generic
    batch_sh = lambda batch: _ns(mesh, sh.batch_specs(batch, mesh, cfg))  # noqa: E731

    def jit_for(batch_abstract):
        return jax.jit(
            train_step,
            in_shardings=(state_sh, batch_sh(batch_abstract)),
            out_shardings=(state_sh, _ns(mesh, jax.tree.map(lambda _: P(), {
                "loss": 0, "nll": 0, "aux": 0, "grad_norm": 0, "lr": 0
            }))),
            donate_argnums=(0,),
        )

    return train_step, abstract, state_sh, jit_for


# ---------------------------------------------------------- prefill step ----


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    """(params, batch) -> (logits (B,V), caches, hidden (B,d))."""

    def prefill_step(params, batch):
        b = jax.tree.leaves(batch)[0].shape[0]
        with axes.axis_rules(mesh, axes.default_rules(cfg, mesh, b)):
            return M.prefill(params, cfg, batch)

    full_abs = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    params_sh = _ns(mesh, sh.param_specs(full_abs, cfg, mesh))

    def jit_for(batch_abstract):
        b = jax.tree.leaves(batch_abstract)[0].shape[0]
        dp = sh.dp_axes_for(b, mesh, cfg.policy.dp_only)
        mdl = "model" if "model" in mesh.axis_names else None
        logits_spec = sh.fit_pspec(P(dp, mdl), (b, cfg.vocab_size), mesh)
        return jax.jit(
            prefill_step,
            in_shardings=(params_sh, _ns(mesh, sh.batch_specs(batch_abstract, mesh, cfg))),
            out_shardings=(
                NamedSharding(mesh, logits_spec),      # logits (B, V)
                None,                                  # caches: let GSPMD place
                NamedSharding(mesh, P(dp, None)),      # hidden (B, d)
            ),
        )

    return prefill_step, full_abs, params_sh, jit_for


# ------------------------------------------------------------ serve step ----


def make_serve_step(cfg: ModelConfig, mesh: Mesh, retrieval: tuple[int, int] | None = None):
    """One decode step: (params, caches, token, pos[, retrieved, ok]) ->
    (logits (B, V), caches, hidden (B, d)).  Caches are donated.

    retrieval=(m, local_window) enables the active-search retrieval-memory
    decode path (sub-quadratic long-context for attention archs)."""

    if retrieval is None:

        def serve_step(params, caches, token, pos):
            with axes.axis_rules(mesh, axes.default_rules(cfg, mesh, token.shape[0])):
                return M.decode_step(params, cfg, caches, token, pos)

    else:
        m, local_window = retrieval

        def serve_step(params, caches, token, pos, retrieved, retrieved_ok):
            with axes.axis_rules(mesh, axes.default_rules(cfg, mesh, token.shape[0])):
                return M.decode_step(
                    params, cfg, caches, token, pos,
                    retrieved=(retrieved, retrieved_ok, local_window),
                )

    full_abs = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    params_sh = _ns(mesh, sh.param_specs(full_abs, cfg, mesh))

    def jit_for(decode_abstract: dict):
        b = decode_abstract["token"].shape[0]
        dp = sh.dp_axes_for(b, mesh, cfg.policy.dp_only)
        mdl = "model" if "model" in mesh.axis_names else None
        logits_spec = sh.fit_pspec(P(dp, mdl), (b, cfg.vocab_size), mesh)
        caches_sh = _ns(mesh, sh.cache_specs(decode_abstract["caches"], cfg, mesh, b))
        in_sh = [params_sh, caches_sh,
                 NamedSharding(mesh, P(dp)), NamedSharding(mesh, P())]
        if retrieval is not None:
            in_sh += [NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P(dp, None))]
        return jax.jit(
            serve_step,
            in_shardings=tuple(in_sh),
            out_shardings=(
                NamedSharding(mesh, logits_spec),  # logits
                caches_sh,
                NamedSharding(mesh, P(dp, None)),  # hidden
            ),
            donate_argnums=(1,),
        )

    return serve_step, full_abs, params_sh, jit_for


# -------------------------------------------- e2e retrieval serve step ------


def make_retrieval_serve_step(cfg: ModelConfig, mesh: Mesh, mem_cfg=None):
    """long_500k serve step with the paper's ACTIVE SEARCH inside the lowered
    program: (params, caches, index, token, pos) -> (logits, caches, hidden).

    Per step: embed the token, summarize its layer-0 query projection, run the
    Eq.-1 radius search + candidate re-rank over the grid index of key
    summaries (all jittable), then decode attending only to
    (local window) U (retrieved positions).  The search cost — the paper's
    contribution — is thereby part of cost_analysis for this cell."""
    from repro.core import engine as eng
    from repro.core import retrieval_memory as rmem

    if mem_cfg is None:
        mem_cfg = rmem.RetrievalMemoryConfig()

    def serve_step(params, caches, index, token, pos):
        x = params["embed"][token][:, None, :].astype(jnp.bfloat16)
        wq0 = params["blocks"][0]["core"]["wq"][0]          # (d, H, hd)
        q0 = jnp.einsum("bsd,dhk->bshk", x, wq0.astype(x.dtype))
        q_sum = jnp.mean(q0[:, 0].astype(jnp.float32), axis=1)   # (B, hd)
        res = eng.ActiveSearcher.from_index(
            index, mem_cfg.grid, plan=mem_cfg.plan
        ).search(q_sum, mem_cfg.n_retrieved)
        retrieved = jnp.maximum(res.ids, 0)
        ok = res.valid & (retrieved < pos)
        return M.decode_step(
            params, cfg, caches, token, pos,
            retrieved=(retrieved, ok, mem_cfg.local_window),
        )

    full_abs = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    params_sh = _ns(mesh, sh.param_specs(full_abs, cfg, mesh))

    def index_abstract(n_keys: int):
        from repro.core.grid import build_index
        from repro.core.projection import Projection

        def build():
            proj = Projection(
                jnp.zeros((cfg.head_dim, 2), jnp.float32),
                jnp.zeros((2,), jnp.float32), jnp.ones((2,), jnp.float32),
            )
            keys = jnp.zeros((n_keys, cfg.head_dim), jnp.float32)
            return build_index(keys, mem_cfg.grid, proj)

        return jax.eval_shape(build)

    def jit_for(decode_abstract: dict, index_abs):
        b = decode_abstract["token"].shape[0]
        dp = sh.dp_axes_for(b, mesh, cfg.policy.dp_only)
        mdl = "model" if "model" in mesh.axis_names else None
        logits_spec = sh.fit_pspec(P(dp, mdl), (b, cfg.vocab_size), mesh)
        caches_sh = _ns(mesh, sh.cache_specs(decode_abstract["caches"], cfg, mesh, b))
        index_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), index_abs)
        return jax.jit(
            serve_step,
            in_shardings=(params_sh, caches_sh, index_sh,
                          NamedSharding(mesh, P(dp)), NamedSharding(mesh, P())),
            out_shardings=(
                NamedSharding(mesh, logits_spec),
                caches_sh,
                NamedSharding(mesh, P(dp, None)),
            ),
            donate_argnums=(1,),
        )

    return serve_step, full_abs, params_sh, index_abstract, jit_for


# ----------------------------------------------------- dry-run cell entry ----


def lower_cell(
    cfg: ModelConfig,
    shape_name: str,
    mesh: Mesh,
    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
    step_cfg: StepConfig = StepConfig(),
    retrieval: tuple[int, int] | None = None,
):
    """Lower one (arch x shape x mesh) cell.  Returns (lowered, kind)."""
    shape = shp.SHAPES[shape_name]
    with mesh:
        if shape.kind == "train":
            _, state_abs, state_sh, jit_for = make_train_step(cfg, opt_cfg, mesh, step_cfg)
            batch_abs = shp.batch_specs(cfg, shape)
            lowered = jit_for(batch_abs).lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            _, params_abs, params_sh, jit_for = make_prefill_step(cfg, mesh)
            batch_abs = shp.batch_specs(cfg, shape)
            lowered = jit_for(batch_abs).lower(params_abs, batch_abs)
        elif shape.kind == "decode" and retrieval is not None:
            # e2e: active search INSIDE the lowered step (index over one key
            # summary per cached position)
            _, params_abs, params_sh, index_abstract, jit_for = (
                make_retrieval_serve_step(cfg, mesh)
            )
            dec = shp.decode_specs(cfg, shape)
            index_abs = index_abstract(shape.seq_len)
            lowered = jit_for(dec, index_abs).lower(
                params_abs, dec["caches"], index_abs, dec["token"], dec["pos"]
            )
        else:  # decode
            _, params_abs, params_sh, jit_for = make_serve_step(cfg, mesh)
            dec = shp.decode_specs(cfg, shape)
            lowered = jit_for(dec).lower(
                params_abs, dec["caches"], dec["token"], dec["pos"]
            )
    return lowered, shape.kind
