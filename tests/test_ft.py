"""Fault tolerance: preemption drain, straggler stats, restart supervisor,
and the full train-loop drills (resume, injected failure, elastic reshard)."""

import os
import signal

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.launch import ft
from repro.launch.mesh import make_host_mesh
from repro.launch.train import TrainConfig, run, train_loop

pytestmark = pytest.mark.slow  # full model/system drills; fast tier skips

def test_step_timer_flags_stragglers():
    t = ft.StepTimer(threshold=2.0, warmup=2)
    for i in range(5):
        t.record(i, 0.1)
    s = t.record(5, 0.5)
    assert s.is_straggler
    s2 = t.record(6, 0.1)
    assert not s2.is_straggler
    assert t.straggler_steps == [5]


def test_step_timer_reshard_after_persistent_slowness():
    t = ft.StepTimer(threshold=1.5, warmup=1)
    t.record(0, 0.1)
    t.record(1, 0.1)
    for i in range(2, 8):
        t.record(i, 1.0)
    assert t.should_reshard(patience=5)


def test_preemption_guard_sets_drain():
    with ft.PreemptionGuard(signals=(signal.SIGUSR1,)) as g:
        assert not g.draining
        os.kill(os.getpid(), signal.SIGUSR1)
        assert g.draining


def test_run_with_restarts_retries_then_succeeds():
    calls = {"n": 0}

    def loop():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return 7

    restarts = []
    out = ft.run_with_restarts(
        loop, max_restarts=5, backoff_s=0.01,
        on_restart=lambda k, e: restarts.append(k),
    )
    assert out == 7
    assert restarts == [1, 2]


def test_run_with_restarts_gives_up():
    def loop():
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        ft.run_with_restarts(loop, max_restarts=2, backoff_s=0.01)


# ------------------------------------------------------- train-loop drills ---


def _tc(tmp_path, **kw):
    kw.setdefault("steps", 6)
    kw.setdefault("batch", 2)
    kw.setdefault("seq", 32)
    kw.setdefault("ckpt_dir", str(tmp_path))
    kw.setdefault("ckpt_every", 2)
    kw.setdefault("log_every", 100)
    return TrainConfig(**kw)


def test_train_resumes_from_checkpoint(tmp_path):
    cfg = get_smoke("internlm2-1.8b")
    mesh = make_host_mesh(1, 1)
    out1 = train_loop(cfg, _tc(tmp_path, steps=4), mesh, log=lambda *_: None)
    assert out1["final_step"] == 4
    # second run continues to 6 (resumed from step-4 checkpoint, not step 0)
    out2 = train_loop(cfg, _tc(tmp_path, steps=6), mesh, log=lambda *_: None)
    assert out2["final_step"] == 6
    assert len(out2["losses"]) == 2  # only steps 4,5 executed


def test_injected_failure_recovers(tmp_path):
    cfg = get_smoke("internlm2-1.8b")
    mesh = make_host_mesh(1, 1)
    tc = _tc(tmp_path, steps=6, fail_at=3)
    out = run(cfg, tc, mesh, max_restarts=2, log=lambda *_: None)
    assert out["final_step"] == 6


def test_elastic_restore_onto_new_mesh(tmp_path):
    """Checkpoint from mesh A restores onto mesh B (1x1 here; the multi-device
    version runs in test_distributed.py via subprocess)."""
    from repro.launch import steps as st
    from repro.optim import adamw
    from repro.checkpoint.store import CheckpointManager

    cfg = get_smoke("internlm2-1.8b")
    mesh = make_host_mesh(1, 1)
    out = train_loop(cfg, _tc(tmp_path, steps=2), mesh, log=lambda *_: None)
    mgr = CheckpointManager(str(tmp_path))
    step_cfg = st.StepConfig()
    abstract = st.train_state_shapes(cfg, adamw.AdamWConfig(), step_cfg)
    sh_b = st._ns(mesh, st.train_state_specs(abstract, cfg, mesh))
    state = mgr.restore(2, abstract, shardings=sh_b)
    got = jax.tree.map(lambda a: np.asarray(a), state["params"]["embed"])
    want = np.asarray(jax.device_get(out["state"]["params"]["embed"]))
    np.testing.assert_array_equal(got, want)
