"""GQA attention with KV cache: chunked-causal train/prefill, O(1) decode,
and the beyond-paper retrieval-augmented decode path (core/retrieval_memory).

Layout conventions (logical axes -> parallel/axes.py rules):
  activations  (B, S, d)           — B -> "batch"
  q/k/v        (B, S, H, hd)       — H -> "heads" (falls back to head_dim)
  KV cache     (B, T, Hkv, hd)     — pinned at the jit boundary (sharding.py)

GQA is computed by REPEATING k/v up to the full query-head count before the
score einsum: on TPU this keeps every attention tensor sharded on one clean
head axis (reshaping q to (Hkv, G) would split the sharded dim — kv=8 over
model=16 cannot divide, and GSPMD falls back to full rematerialization).
The repeat is free under remat and the expanded k/v are (B,S,Hq,hd)/TP-sharded.

Scores/softmax accumulate in fp32; everything else runs in bf16.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.axes import constrain
from repro.utils import scan as uscan


def init_attention(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.hq_eff, cfg.hkv_eff   # padded for TP divisibility
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(k1, (d, hq, hd), fan_in=d),
        "wk": L.dense_init(k2, (d, hkv, hd), fan_in=d),
        "wv": L.dense_init(k3, (d, hkv, hd), fan_in=d),
        "wo": L.dense_init(k4, (hq, hd, d), fan_in=cfg.n_heads * hd),
    }


def _head_mask(cfg: ModelConfig, out: jax.Array) -> jax.Array:
    """Zero the padded heads' outputs: pad heads contribute nothing and
    receive no gradient — model capacity stays exactly the assigned config."""
    if cfg.hq_eff == cfg.n_heads:
        return out
    mask = (jnp.arange(cfg.hq_eff) < cfg.n_heads).astype(out.dtype)
    return out * mask[None, None, :, None]


def _qkv(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    """Projections + RoPE + logical sharding pins.  positions: (S,) int32."""
    xd = x.astype(L.ACT_DTYPE)
    q = jnp.einsum("bsd,dhk->bshk", xd, params["wq"].astype(xd.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xd, params["wk"].astype(xd.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xd, params["wv"].astype(xd.dtype))
    cos, sin = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos[None, :, None, :], sin[None, :, None, :])
    k = L.apply_rope(k, cos[None, :, None, :], sin[None, :, None, :])
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, T, Hkv, hd) -> (B, T, Hq, hd) by repeating each kv head G times."""
    hkv = k.shape[2]
    if hkv == n_heads:
        return k
    rep = jnp.repeat(k, n_heads // hkv, axis=2)
    return constrain(rep, "batch", "seq", "heads", "head_dim")


def _sdpa(
    q: jax.Array,        # (B, S, H, hd)
    k: jax.Array,        # (B, T, H, hd) — already GQA-expanded
    v: jax.Array,        # (B, T, H, hd)
    mask: jax.Array,     # (S, T) or (B, S, T) bool — True = attend
) -> jax.Array:
    hd = q.shape[-1]
    scores = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    m = mask[None, None] if mask.ndim == 2 else mask[:, None]
    scores = jnp.where(m, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthk->bshk", probs, v)


def causal_attention(
    q: jax.Array,   # (B, S, Hq, hd)
    k: jax.Array,   # (B, S, Hkv, hd)
    v: jax.Array,
    chunk: int = 1024,
) -> jax.Array:
    """Causal self-attention, scanned over query chunks so the (cq, S) score
    block — not (S, S) — is the peak intermediate.  O(S^2) FLOPs, O(S*cq) mem."""
    b, s, hq, hd = q.shape
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)

    if s <= chunk:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        return _sdpa(q, k, v, mask)

    assert s % chunk == 0, f"seq {s} must divide chunk {chunk}"
    nc = s // chunk
    qc = jnp.moveaxis(q.reshape(b, nc, chunk, hq, hd), 1, 0)
    kv_pos = jnp.arange(s, dtype=jnp.int32)

    def step(_, inp):
        qi, ci = inp
        q_pos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        mask = q_pos[:, None] >= kv_pos[None, :]
        return None, _sdpa(qi, k, v, mask)

    _, outs = uscan.scan(step, None, (qc, jnp.arange(nc, dtype=jnp.int32)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, hq, hd)


def attention_block(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,          # (B, S, d)
    positions: jax.Array,  # (S,) int32
    chunk: int = 1024,
) -> jax.Array:
    """Full self-attention sublayer (projections + RoPE + causal attention)."""
    q, k, v = _qkv(params, cfg, x, positions)
    out = _head_mask(cfg, causal_attention(q, k, v, chunk=chunk))
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(out.dtype))


# ---------------------------------------------------------------- decode ----


def prefill_cache(
    params: dict, cfg: ModelConfig, x: jax.Array, positions: jax.Array, cache_len: int
) -> tuple[jax.Array, dict]:
    """Like attention_block but also materializes the KV cache (B, T, Hkv, hd)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, cfg, x, positions)
    out = _head_mask(cfg, causal_attention(q, k, v, chunk=min(cfg.policy.attn_chunk, s)))
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(out.dtype))

    kc = jnp.zeros((b, cache_len, cfg.hkv_eff, cfg.head_dim), L.ACT_DTYPE)
    vc = jnp.zeros_like(kc)
    kc = lax.dynamic_update_slice(kc, k.astype(L.ACT_DTYPE), (0, 0, 0, 0))
    vc = lax.dynamic_update_slice(vc, v.astype(L.ACT_DTYPE), (0, 0, 0, 0))
    return out, {"k": kc, "v": vc}


def _expand_kv_decode(k: jax.Array, n_heads: int) -> jax.Array:
    """GQA expand on the cache layout: follows the cache's own sharding
    (kv-heads OR head_dim) instead of forcing the train-time heads layout."""
    hkv = k.shape[2]
    rep = k if hkv == n_heads else jnp.repeat(k, n_heads // hkv, axis=2)
    return constrain(rep, "batch", "seq", "dec_heads", "dec_hd")


def decode_attention(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,        # (B, 1, d)
    cache: dict,         # {"k","v"}: (B, T, Hkv, hd)
    pos: jax.Array,      # () int32 — write/attend position (tokens < pos+1 valid)
) -> tuple[jax.Array, dict]:
    """One-token decode: write k/v at `pos`, attend over positions <= pos."""
    t = cache["k"].shape[1]
    q, k, v = _qkv(params, cfg, x, pos[None])
    q = constrain(q, "batch", "seq", "dec_heads", "dec_hd")

    kc = lax.dynamic_update_slice(cache["k"], k.astype(L.ACT_DTYPE), (0, pos, 0, 0))
    vc = lax.dynamic_update_slice(cache["v"], v.astype(L.ACT_DTYPE), (0, pos, 0, 0))

    ke = _expand_kv_decode(kc, cfg.hq_eff)
    ve = _expand_kv_decode(vc, cfg.hq_eff)
    mask = (jnp.arange(t, dtype=jnp.int32) <= pos)[None, :]       # (1, T)
    out = _head_mask(cfg, _sdpa(q, ke, ve, mask))
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(out.dtype))
    return out, {"k": kc, "v": vc}


def decode_attention_retrieved(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,            # (B, 1, d)
    cache: dict,             # full-length cache (B, T, Hkv, hd)
    pos: jax.Array,          # () int32
    retrieved: jax.Array,    # (B, m) int32 — positions from active search
    retrieved_ok: jax.Array,  # (B, m) bool
    local_window: int,
) -> tuple[jax.Array, dict]:
    """Sub-quadratic decode: attend over {local window} U {retrieved positions}
    instead of the whole cache.  Per-step cost O(w + m) — N-independence of the
    paper's search carried into attention (DESIGN.md §5)."""
    b = x.shape[0]
    t = cache["k"].shape[1]
    q, k, v = _qkv(params, cfg, x, pos[None])

    kc = lax.dynamic_update_slice(cache["k"], k.astype(L.ACT_DTYPE), (0, pos, 0, 0))
    vc = lax.dynamic_update_slice(cache["v"], v.astype(L.ACT_DTYPE), (0, pos, 0, 0))

    # gather the attended positions: local window (w) + retrieved (m)
    w = local_window
    local = pos - w + 1 + jnp.arange(w, dtype=jnp.int32)          # (w,), may be <0
    local_ok = local >= 0
    local = jnp.clip(local, 0, t - 1)
    idx = jnp.concatenate(
        [jnp.broadcast_to(local, (b, w)), jnp.clip(retrieved, 0, t - 1)], axis=1
    )                                                              # (B, w+m)
    ok = jnp.concatenate(
        [
            jnp.broadcast_to(local_ok, (b, w)),
            # retrieved entries inside the local window would be double
            # counted by the softmax — mask them out
            retrieved_ok & (retrieved <= pos) & (retrieved < pos - w + 1),
        ],
        axis=1,
    )
    kg = jnp.take_along_axis(kc, idx[:, :, None, None], axis=1)   # (B, w+m, Hkv, hd)
    vg = jnp.take_along_axis(vc, idx[:, :, None, None], axis=1)

    ke = _expand_kv_decode(kg, cfg.hq_eff)
    ve = _expand_kv_decode(vg, cfg.hq_eff)
    out = _head_mask(cfg, _sdpa(q, ke, ve, ok[:, None, :]))
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(out.dtype))
    return out, {"k": kc, "v": vc}
