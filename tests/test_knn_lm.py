"""kNN-LM head + retrieval memory (the paper's technique inside the LM)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import knn_lm, retrieval_memory as rmem
from repro.core.grid import GridConfig


def _store(rng, n=2048, d=16):
    keys = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, 64, size=n), jnp.int32)
    cfg = knn_lm.KNNLMConfig(k=8, lam=0.3)
    return keys, toks, cfg, knn_lm.build_datastore(keys, toks, cfg)


def test_knn_logprobs_normalized(rng):
    keys, toks, cfg, idx = _store(rng)
    h = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    logp = knn_lm.knn_logprobs(idx, cfg, h, vocab_size=64)
    p = np.exp(np.asarray(logp))
    assert p.shape == (4, 64)
    np.testing.assert_allclose(p.sum(axis=1), 1.0, atol=1e-3)


def test_knn_retrieves_exact_key(rng):
    """Querying WITH a stored key must put mass on that key's token."""
    keys, toks, cfg, idx = _store(rng)
    qi = 17
    logp = knn_lm.knn_logprobs(idx, cfg, keys[qi:qi + 1], vocab_size=64)
    tok = int(toks[qi])
    assert float(np.exp(logp[0, tok])) > 1.0 / 64


def test_knn_logprobs_no_neighbors_is_uniform(rng):
    """Regression: a query whose candidate window retrieves NOTHING (sparse
    datastore) must yield the uniform distribution, not softmax-nan zeros —
    p_knn has to normalize for every lane."""
    keys = jnp.asarray(rng.normal(size=(32, 16)) * 0.01, jnp.float32)  # tight cluster
    toks = jnp.asarray(rng.integers(0, 64, size=32), jnp.int32)
    cfg = knn_lm.KNNLMConfig(k=8)
    idx = knn_lm.build_datastore(keys, toks, cfg)
    # one in-cluster query, one absurdly far away (projects off-grid, clips
    # to an empty corner window)
    h = jnp.stack([keys[0], jnp.full((16,), 1e4, jnp.float32)])
    logp = knn_lm.knn_logprobs(idx, cfg, h, vocab_size=64)
    p = np.exp(np.asarray(logp))
    np.testing.assert_allclose(p.sum(axis=1), 1.0, atol=1e-3)
    res = knn_lm.ActiveSearcher.from_index(idx, cfg.grid).search(h, cfg.k)
    if not bool(np.asarray(res.valid[1]).any()):  # the case under test
        np.testing.assert_allclose(p[1], 1.0 / 64, rtol=1e-5)


def test_interpolate_is_logaddexp(rng):
    cfg = knn_lm.KNNLMConfig(lam=0.25)
    lm = jnp.asarray(rng.normal(size=(2, 10)), jnp.float32)
    knn_lp = jax.nn.log_softmax(jnp.asarray(rng.normal(size=(2, 10)), jnp.float32))
    out = knn_lm.interpolate(lm, knn_lp, cfg)
    want = np.log(
        0.25 * np.exp(np.asarray(knn_lp))
        + 0.75 * np.asarray(jax.nn.softmax(lm, axis=-1))
    )
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-5)
    # still a distribution
    np.testing.assert_allclose(np.exp(np.asarray(out)).sum(-1), 1.0, atol=1e-4)


def test_retrieval_memory_returns_valid_past_positions(rng):
    cfg = rmem.RetrievalMemoryConfig(n_retrieved=8)
    proj = rmem.make_projection(jax.random.PRNGKey(0), head_dim=16)
    keys = jnp.asarray(rng.normal(size=(512, 16)) * 0.3, jnp.float32)
    idx = rmem.build_memory_index(keys, cfg, proj)
    q = keys[100:102]
    pos, ok = rmem.retrieve_positions(idx, cfg, q)
    assert pos.shape == (2, 8)
    assert bool(ok.any())
    assert int(pos.max()) < 512 and int(pos.min()) >= 0
    # querying with a stored key must retrieve its own position
    assert 100 in np.asarray(pos[0])


def test_key_query_summaries(rng):
    k = jnp.asarray(rng.normal(size=(32, 4, 16)), jnp.float32)
    s = rmem.key_summary(k)
    assert s.shape == (32, 16)
    np.testing.assert_allclose(np.asarray(s), np.asarray(k.mean(axis=1)), rtol=1e-6)
