"""lax.scan wrapper that can unroll at trace time — cost-probe support.

XLA's cost_analysis() counts a while-loop body ONCE, so any scanned model
under-reports FLOPs/bytes/collectives.  The dry-run cost probes re-trace the
model with inner chunk scans UNROLLED (and the layer stack at depth 1 and 2,
extrapolated affinely), which makes cost_analysis exact.  Production traces
keep lax.scan (compile time, memory).

Only chunk-loops go through this wrapper (attention q-chunks, mamba chunks,
mLSTM chunks).  The sLSTM time scan stays a lax.scan always: its per-step
recurrent einsum is <1% of model FLOPs (documented in EXPERIMENTS.md §Dry-run
methodology) and unrolling seq_len steps is not tractable.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp
from jax import lax

_UNROLL: contextvars.ContextVar = contextvars.ContextVar("unroll_scans", default=False)
MAX_UNROLL = 4096


@contextlib.contextmanager
def unroll_scans():
    tok = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


def unrolling() -> bool:
    return bool(_UNROLL.get())


def scan(f, init, xs, length: int | None = None):
    """Drop-in for lax.scan(f, init, xs) on chunk loops."""
    if not _UNROLL.get():
        return lax.scan(f, init, xs, length=length)
    if length is None:
        length = jax.tree.leaves(xs)[0].shape[0]
    if length > MAX_UNROLL:
        return lax.scan(f, init, xs, length=length)
    carry = init
    ys = []
    for i in range(length):
        x_i = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, x_i)
        ys.append(y)
    stacked = (
        None
        if ys[0] is None
        else jax.tree.map(lambda *a: jnp.stack(a), *ys)
    )
    return carry, stacked
