"""Active search vs the exact-kNN oracle: recall, classification, Eq. 1."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as hst

from repro.core import active_search as act
from repro.core import exact
from repro.core import pyramid as pyr
from repro.core.grid import GridConfig, build_index
from repro.core.projection import identity_projection


def _setup(rng, n=2000, k_classes=3, grid=256):
    pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, k_classes, size=n), jnp.int32)
    cfg = GridConfig(grid_size=grid, tile=16, n_classes=k_classes,
                     window=48, row_cap=48, r0=10, k_slack=2.0, max_iters=16)
    proj = identity_projection(pts)
    return pts, labels, cfg, build_index(pts, cfg, proj, labels=labels)


def test_refined_recall_high(rng):
    pts, labels, cfg, idx = _setup(rng)
    q = jnp.asarray(rng.normal(size=(64, 2)), jnp.float32)
    res = act.search(idx, cfg, q, 11, mode="refined")
    ex = exact.knn(q, pts, 11)
    recall = np.mean([
        len(set(np.asarray(res.ids[i]).tolist()) & set(np.asarray(ex.ids[i]).tolist())) / 11
        for i in range(64)
    ])
    assert recall > 0.9, recall


def test_refined_dists_sorted_and_correct(rng):
    pts, _, cfg, idx = _setup(rng, n=800)
    q = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)
    res = act.search(idx, cfg, q, 5, mode="refined")
    d = np.asarray(res.dists)
    assert (np.diff(d, axis=1) >= -1e-6).all()
    # distances match the true metric for returned ids
    for i in range(8):
        for j in range(5):
            if res.valid[i, j]:
                pid = int(res.ids[i, j])
                true = float(jnp.linalg.norm(pts[pid] - q[i]))
                assert abs(true - float(res.dists[i, j])) < 1e-4


def test_paper_mode_counts(rng):
    """Paper mode returns points inside the final circle, by grid distance."""
    pts, labels, cfg, idx = _setup(rng, n=1000)
    q = jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)
    res = act.search(idx, cfg, q, 11, mode="paper")
    assert res.ids.shape == (16, 11)
    assert bool(jnp.all(res.count[res.converged] >= 11))


def test_classify_matches_exact_mostly(rng):
    pts, labels, cfg, idx = _setup(rng, n=3000)
    q = jnp.asarray(rng.normal(size=(100, 2)), jnp.float32)
    pred = act.classify(idx, cfg, q, 11, mode="refined")
    truth = exact.classify(q, pts, labels, 11, n_classes=3)
    acc = float(jnp.mean((pred == truth).astype(jnp.float32)))
    assert acc >= 0.9, acc  # paper reports up to 98% on this setup


def test_radius_search_reaches_k(rng):
    pts, _, cfg, idx = _setup(rng, n=2000)
    q = jnp.asarray(rng.normal(size=(2,)), jnp.float32)
    from repro.core import projection as pl
    qg = pl.to_grid_coords(idx.proj, q, cfg.grid_size)
    stats = pyr.radius_search(idx, cfg, qg, 11)
    assert int(stats["count"]) >= 11
    assert int(stats["radius"]) >= 1


def test_count_in_circle_matches_bruteforce(rng):
    pts, _, cfg, idx = _setup(rng, n=500)
    from repro.core import projection as pl
    coords = np.asarray(pl.to_grid_coords(idx.proj, pts, cfg.grid_size))
    centers = np.floor(coords) + 0.5
    q = jnp.asarray([cfg.grid_size / 2, cfg.grid_size / 2], jnp.float32)
    for r in (3, 10, 40):
        got = int(pyr.count_total(idx, cfg, q, jnp.int32(r)))
        lvl = int(pyr.level_for_radius(jnp.int32(r), cfg))
        if lvl == 0:  # exact at base level
            want = int((((centers - np.asarray(q)) ** 2).sum(axis=1) <= r * r).sum())
            assert got == want, (r, got, want)
        else:  # coarser levels approximate; mass is bounded by window total
            assert 0 <= got <= 500


def test_l1_metric(rng):
    pts = jnp.asarray(rng.normal(size=(1000, 2)), jnp.float32)
    cfg = GridConfig(grid_size=128, tile=16, window=48, row_cap=48, r0=8,
                     k_slack=2.0, metric="l1")
    idx = build_index(pts, cfg, identity_projection(pts))
    q = jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)
    res = act.search(idx, cfg, q, 7)
    # L1 distances
    for i in range(4):
        if res.valid[i, 0]:
            pid = int(res.ids[i, 0])
            want = float(jnp.sum(jnp.abs(pts[pid] - q[i])))
            assert abs(want - float(res.dists[i, 0])) < 1e-4


def test_truncation_flag_when_window_too_small(rng):
    """A huge k forces the circle past the candidate window -> truncated."""
    pts = jnp.asarray(rng.normal(size=(500, 2)), jnp.float32)
    cfg = GridConfig(grid_size=256, tile=16, window=8, row_cap=8, r0=4,
                     k_slack=1.5)
    idx = build_index(pts, cfg, identity_projection(pts))
    q = jnp.zeros((1, 2), jnp.float32)
    res = act.search(idx, cfg, q, 200)
    assert bool(res.truncated[0])


@settings(max_examples=15, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1), k=hst.integers(1, 20))
def test_property_refined_subset_of_window_is_exact(seed, k):
    """Within the candidate window, refined results == exact kNN restricted
    to those candidates (the re-rank is exact by construction)."""
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.normal(size=(300, 2)), jnp.float32)
    cfg = GridConfig(grid_size=64, tile=8, window=24, row_cap=64, r0=4,
                     k_slack=2.0)
    idx = build_index(pts, cfg, identity_projection(pts))
    q = jnp.asarray(rng.normal(size=(1, 2)), jnp.float32)
    res = act.search(idx, cfg, q, k)
    valid = np.asarray(res.valid[0])
    ids = np.asarray(res.ids[0])[valid]
    dists = np.asarray(res.dists[0])[valid]
    assert len(set(ids.tolist())) == len(ids)          # no duplicates
    assert (np.diff(dists) >= -1e-6).all()             # sorted


def test_eq1_update_rule():
    """r' = round(r * sqrt(k / n)) — the paper's Eq. 1, directly."""
    r, k, n = jnp.int32(100), 11, jnp.int32(44)
    ratio = jnp.sqrt(k / jnp.maximum(n, 1).astype(jnp.float32))
    r_new = jnp.round(r.astype(jnp.float32) * ratio).astype(jnp.int32)
    assert int(r_new) == 50  # sqrt(11/44) = 1/2
