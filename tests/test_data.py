"""Data pipeline: determinism, host disjointness, prefetch, restart."""

import numpy as np

from repro.data.pipeline import DataConfig, Prefetcher, synth_batch
from repro.configs import get_smoke


def test_step_determinism():
    cfg = DataConfig(global_batch=8, seq_len=32, vocab_size=128, seed=3)
    a = synth_batch(cfg, 7)
    b = synth_batch(cfg, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(cfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(global_batch=2, seq_len=16, vocab_size=64)
    b = synth_batch(cfg, 0)
    assert b["tokens"].shape == (2, 16)
    assert b["labels"].shape == (2, 16)


def test_hosts_partition_the_global_batch():
    full = synth_batch(DataConfig(global_batch=8, seq_len=8, vocab_size=32), 5)
    rows = []
    for h in (0, 1):
        cfg = DataConfig(global_batch=8, seq_len=8, vocab_size=32, n_hosts=2, host_id=h)
        rows.append(synth_batch(cfg, 5)["tokens"])
    stacked = np.concatenate(rows, axis=0)
    np.testing.assert_array_equal(stacked, full["tokens"])


def test_prefetcher_order_and_restart():
    cfg = DataConfig(global_batch=2, seq_len=8, vocab_size=32, prefetch=2)
    pf = Prefetcher(cfg, start_step=10)
    s0, b0 = next(pf)
    s1, b1 = next(pf)
    pf.close()
    assert (s0, s1) == (10, 11)
    np.testing.assert_array_equal(b0["tokens"], synth_batch(cfg, 10)["tokens"])


def test_frontend_inputs_attached():
    from repro.data.pipeline import add_frontend_inputs
    cfg = DataConfig(global_batch=2, seq_len=8, vocab_size=256)
    mcfg = get_smoke("musicgen-medium")
    b = add_frontend_inputs(synth_batch(cfg, 0), mcfg, 0)
    assert b["frame_embeds"].shape == (2, 8, mcfg.d_model)
    vcfg = get_smoke("internvl2-1b")
    b2 = add_frontend_inputs(synth_batch(cfg, 1), vcfg, 1)
    assert b2["vision_embeds"].shape == (2, vcfg.n_frontend_tokens, vcfg.d_model)
