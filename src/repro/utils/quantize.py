"""Shared symmetric int8 round-trip helpers.

One definition of the int8 codec used everywhere the repo trades precision
for bandwidth, so the numerics can never drift apart:

  * `optim/compression.py` — per-tensor gradient compression (the
    error-feedback wrapper stays there; only the raw round-trip lives here).
  * `core/quantized.py` — the per-cell quantized candidate store behind the
    `pallas_q8` backend (one scale per CSR cell, broadcast per row).

Symmetric codebook: `scale = max(|x|) / 127` (eps-floored so all-zero
inputs stay representable), `q = clip(round(x / scale), -127, 127)`.
-128 is never produced, so negation round-trips and the TPU int8 path never
sees the asymmetric edge value.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# int8 symmetric codebook half-range: values land in [-127, 127]
QMAX = 127
_EPS = 1e-12


def symmetric_scale(max_abs: jax.Array) -> jax.Array:
    """Per-group scale from a (broadcastable) max-|x| statistic."""
    return jnp.maximum(max_abs, _EPS).astype(jnp.float32) / QMAX


def quantize_with_scale(x: jax.Array, scale: jax.Array) -> jax.Array:
    """int8 codes for `x` under an externally chosen (broadcastable) scale."""
    return jnp.clip(jnp.round(x / scale), -QMAX, QMAX).astype(jnp.int8)


def quantize_symmetric(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q int8, scale f32 scalar)."""
    scale = symmetric_scale(jnp.max(jnp.abs(x)))
    return quantize_with_scale(x, scale), scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    """float32 reconstruction of int8 codes under a (broadcastable) scale."""
    return q.astype(jnp.float32) * scale
