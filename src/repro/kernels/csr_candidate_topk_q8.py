"""Pallas TPU kernel: int8 CSR candidate scoring -> top-`rerank_k` shortlist.

The exact fused kernel (`csr_candidate_topk.py`) is bandwidth-bound on its
row DMAs: every window row moves `row_cap * d` float32s.  This variant is
the coarse half of the quantized candidate path (`pallas_q8` backend): it
DMAs the candidate rows from the INT8 store (`core/quantized.py`, per-cell
symmetric scales) at a quarter of the bytes, scores them with int32
arithmetic on the VPU, and streams a top-`rerank_k` shortlist of global
CSR row indices.  The caller then exact-re-ranks ONLY those `rerank_k`
rows against the fp32 store (a second, small DMA) with the existing
streaming top-k (`candidate_topk`), so the final (dists, indices) are full
fp32 — see `core/batched.py`.

Scoring, per window row (one double-buffered int8 row DMA + one tiny
`(row_cap, 1)` scale DMA):

  qs   = clip(round(q / s_row), -QCLIP, QCLIP)       int32 (row_cap, d)
  diff = q_points.int32 - qs                          int32
  l2:  acc = sum_chunks f32(sum_chunk diff^2)         int32 inside a chunk
  l1:  acc = sum_chunks   (sum_chunk |diff|)          int32 throughout
  score = s_row * sqrt(acc)   (l2)   |   s_row * acc  (l1)

The query is re-quantized against each row's (= its cell's) scale, so the
integer difference is meaningful per cell; QCLIP bounds the code so a
`<= Q8_MAX_CHUNK`-dim chunk's sum of squares cannot overflow int32 (the
wrapper caps the accumulation chunk accordingly — queries farther than
QCLIP/127 cell-ranges score saturated-far, which only ever demotes
candidates that the exact re-rank would reject anyway).  Scores are
APPROXIMATE by design: the contract is recall (the true top-k lands in the
shortlist), not bit-parity — but masking and tie-breaks (clamped span
starts, row-major window order, first-index argmin) are IDENTICAL to the
exact kernel, so when the shortlist does contain the exact top-k, the
downstream re-rank reproduces `pallas` bit-for-bit
(tests/test_quantized.py).  Validated with interpret=True against
ref.csr_shortlist_q8 (exact match: integer scoring is deterministic).

VMEM per program: 2 * row_cap * d int8 + 2 * row_cap floats of row buffer
(vs 2 * row_cap * d floats for the fp32 kernel) + the same
2 * w * row_cap accumulator lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# query codes are clipped to +/-QCLIP cell-ranges; with diff bounded by
# QCLIP + 127 a chunk of Q8_MAX_CHUNK dims accumulates |diff|^2 in int32
# with ~3x headroom: 512 * (1023 + 127)^2 < 2^31
QCLIP = 1023
Q8_MAX_CHUNK = 512


def _kernel(
    span_ref,    # scalar prefetch: (B, 2w) int32 — [starts | ends] CSR spans
    q_ref,       # (1, d) float32 — this query's ranking vector
    store_ref,   # (n_pad, d) int8 — quantized CSR store, stays in HBM/ANY
    scale_ref,   # (n_pad, 1) float32 — per-row (= per-cell) scales, HBM/ANY
    outd_ref,    # (1, rerank_k) float32 — approximate scores (+inf pads)
    outi_ref,    # (1, rerank_k) int32 — global CSR row indices (-1 pads)
    buf_ref,     # scratch (2, row_cap, d) int8 — double-buffered rows
    sbuf_ref,    # scratch (2, row_cap, 1) float32 — double-buffered scales
    dist_ref,    # scratch (1, w*row_cap) float32
    gidx_ref,    # scratch (1, w*row_cap) int32
    sem,         # DMA semaphores (2,) — row buffers
    ssem,        # DMA semaphores (2,) — scale buffers
    *,
    w: int,
    row_cap: int,
    rerank_k: int,
    n: int,
    n_pad: int,
    d_chunks: tuple[tuple[int, int], ...],
    metric: str,
):
    i = pl.program_id(0)
    q = q_ref[...]                            # (1, d)
    s_max = max(n_pad - row_cap, 0)

    def s_cl(row):
        # same clamp as the exact kernel: identical candidate order
        return jnp.clip(span_ref[i, row], 0, s_max)

    def row_dma(slot, row):
        return pltpu.make_async_copy(
            store_ref.at[pl.ds(s_cl(row), row_cap)],
            buf_ref.at[slot],
            sem.at[slot],
        )

    def scale_dma(slot, row):
        return pltpu.make_async_copy(
            scale_ref.at[pl.ds(s_cl(row), row_cap)],
            sbuf_ref.at[slot],
            ssem.at[slot],
        )

    row_dma(0, 0).start()
    scale_dma(0, 0).start()

    def body(row, carry):
        slot = jax.lax.rem(row, 2)

        @pl.when(row + 1 < w)
        def _prefetch_next():
            nxt = jax.lax.rem(row + 1, 2)
            row_dma(nxt, row + 1).start()
            scale_dma(nxt, row + 1).start()

        row_dma(slot, row).wait()
        scale_dma(slot, row).wait()
        s = sbuf_ref[slot]                    # (row_cap, 1) float32
        qs = jnp.clip(
            jnp.round(q / s), -QCLIP, QCLIP
        ).astype(jnp.int32)                   # (row_cap, d)
        diff = buf_ref[slot].astype(jnp.int32) - qs
        if metric == "l1":
            acc = sum(
                jnp.sum(jnp.abs(diff[:, c0:c0 + dc]), axis=1)
                for c0, dc in d_chunks
            )                                 # int32 (row_cap,)
            dist = s[:, 0] * acc.astype(jnp.float32)
        else:
            acc = sum(
                jnp.sum(
                    diff[:, c0:c0 + dc] * diff[:, c0:c0 + dc], axis=1
                ).astype(jnp.float32)         # int32 inside the chunk only
                for c0, dc in d_chunks
            )
            dist = s[:, 0] * jnp.sqrt(acc)
        j = s_cl(row) + jax.lax.broadcasted_iota(jnp.int32, (row_cap,), 0)
        ok = (j >= span_ref[i, row]) & (j < span_ref[i, w + row]) & (j < n)
        dist_ref[0, pl.ds(row * row_cap, row_cap)] = jnp.where(
            ok, dist, jnp.inf
        )
        gidx_ref[0, pl.ds(row * row_cap, row_cap)] = j
        return carry

    jax.lax.fori_loop(0, w, body, 0)

    dcur = dist_ref[...]                      # (1, w*row_cap)
    col = jax.lax.broadcasted_iota(jnp.int32, dcur.shape, 1)
    dists, idxs = [], []
    for _ in range(rerank_k):
        m = jnp.min(dcur, axis=1)             # (1,)
        am = jnp.argmin(dcur, axis=1)         # (1,) first-index ties
        dists.append(m[0])
        g = gidx_ref[0, am[0]]
        idxs.append(jnp.where(jnp.isfinite(m[0]), g, -1))
        dcur = jnp.where(col == am[:, None], jnp.inf, dcur)
    outd_ref[0, :] = jnp.stack(dists)
    outi_ref[0, :] = jnp.stack(idxs)


def q8_d_chunks(d: int, d_chunk: int | None) -> tuple[tuple[int, int], ...]:
    """The (start, size) accumulation chunks for a d-dim q8 score.

    Unlike the exact kernel (d_chunk=None = ONE reassociation-free sum, for
    bit-parity with the jnp path), the q8 score is approximate by contract,
    so the chunk is always capped at Q8_MAX_CHUNK — the int32 overflow
    bound — and d_chunk only tightens it further.  Shared with the ref
    oracle so kernel and oracle always agree on the summation tree.
    """
    dc = d if d_chunk is None else max(1, min(d_chunk, d))
    dc = min(dc, Q8_MAX_CHUNK)
    return tuple((c0, min(dc, d - c0)) for c0 in range(0, d, dc))


@functools.partial(
    jax.jit,
    static_argnames=(
        "rerank_k", "n", "row_cap", "metric", "d_chunk", "interpret"
    ),
)
def csr_shortlist_q8(
    q_store: jax.Array,     # (n_pad, d) int8 — quantized CSR store
    row_scales: jax.Array,  # (n_pad, 1) float32 — per-row cell scales
    starts: jax.Array,      # (B, w) int32 — window-row span starts
    ends: jax.Array,        # (B, w) int32 — window-row span ends
    queries: jax.Array,     # (B, d) float32 — per-query ranking vectors
    rerank_k: int,
    n: int,                 # live CSR rows (store rows >= n are padding)
    row_cap: int,
    metric: str = "l2",
    d_chunk: int | None = None,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Contract identical to ref.csr_shortlist_q8.

    Returns (scores (B, rerank_k) float32 approximate, +inf pads; idx
    (B, rerank_k) int32 GLOBAL CSR row indices with -1 pads), best-first.
    """
    n_pad, d = q_store.shape
    b, w = starts.shape
    if q_store.dtype != jnp.int8:
        raise ValueError(f"q_store must be int8, got {q_store.dtype}")
    if row_scales.shape != (n_pad, 1):
        raise ValueError(
            f"row_scales shape {row_scales.shape} != ({n_pad}, 1); one "
            f"scale per padded CSR row (core/quantized.py)"
        )
    if n_pad < row_cap:
        raise ValueError(
            f"store has {n_pad} rows but row_cap={row_cap}; pad the store "
            f"(active_search.padded_csr) so every span slice is in bounds"
        )
    if ends.shape != (b, w):
        raise ValueError(f"ends shape {ends.shape} != starts {starts.shape}")
    if queries.shape != (b, d):
        raise ValueError(
            f"queries shape {queries.shape} does not match spans batch "
            f"{b} x store dim {d}"
        )
    if not 1 <= rerank_k <= w * row_cap:
        raise ValueError(
            f"rerank_k={rerank_k} must be in [1, window*row_cap = "
            f"{w * row_cap}] (the shortlist is drawn from one window)"
        )
    d_chunks = q8_d_chunks(d, d_chunk)

    spans = jnp.concatenate([starts, ends], axis=1).astype(jnp.int32)
    kernel = functools.partial(
        _kernel,
        w=w, row_cap=row_cap, rerank_k=rerank_k, n=n, n_pad=n_pad,
        d_chunks=d_chunks, metric=metric,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, *_: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # int8 store: manual DMA
            pl.BlockSpec(memory_space=pltpu.ANY),  # scales: manual DMA
        ],
        out_specs=[
            pl.BlockSpec((1, rerank_k), lambda i, *_: (i, 0)),
            pl.BlockSpec((1, rerank_k), lambda i, *_: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, row_cap, d), jnp.int8),
            pltpu.VMEM((2, row_cap, 1), jnp.float32),
            pltpu.VMEM((1, w * row_cap), jnp.float32),
            pltpu.VMEM((1, w * row_cap), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, rerank_k), jnp.float32),
            jax.ShapeDtypeStruct((b, rerank_k), jnp.int32),
        ],
        interpret=interpret,
    )(
        spans,
        queries.astype(jnp.float32),
        q_store,
        row_scales.astype(jnp.float32),
    )
