"""internlm2-1.8b [dense] — GQA (arXiv:2403.17297; hf).

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
long_500k: SKIP (pure full attention)."""

from repro.models.config import ModelConfig, ParallelismPolicy

LONG_CONTEXT = "skip"

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    head_dim=128,
    policy=ParallelismPolicy(remat="full", scan_layers=True, accum=4),
)

SMOKE = ModelConfig(
    name="internlm2-1.8b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
)
