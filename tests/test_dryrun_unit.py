"""Dry-run machinery unit tests: HLO collective parsing, roofline math,
cell planning.  (The real 512-device dry-run runs via dryrun.py; its results
land in EXPERIMENTS.md.)"""

import numpy as np
import pytest

from repro.launch import roofline as rl


HLO = """
HloModule jit_step
ENTRY %main {
  %p0 = bf16[16,4096]{1,0} parameter(0)
  %ag = bf16[256,4096]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%sum
  %rs = f32[64,32]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = bf16[8,8]{1,0} all-to-all(%z)
  %cp = u8[100]{0} collective-permute(%w)
  %ags = (f32[128,8]{1,0}, f32[128,8]{1,0}) all-gather-start(%q)
  %agd = (f32[128,8]{1,0}, f32[128,8]{1,0}) all-gather-done(%ags)
  %not = f32[999]{0} add(%a, %b)
}
"""


def test_collective_bytes_parses_kinds():
    got = rl.collective_bytes(HLO)
    assert got["all-gather"] == 256 * 4096 * 2 + 2 * 128 * 8 * 4  # sync + start
    assert got["all-reduce"] == 1024 * 4
    assert got["reduce-scatter"] == 64 * 32 * 4
    assert got["all-to-all"] == 8 * 8 * 2
    assert got["collective-permute"] == 100


def test_done_ops_not_double_counted():
    two_starts = HLO + HLO  # paranoia: parser is line-based and stateless
    got = rl.collective_bytes(two_starts)
    assert got["all-gather"] == 2 * (256 * 4096 * 2 + 2 * 128 * 8 * 4)


def test_roofline_terms_and_bottleneck():
    r = rl.Roofline(
        flops=197e12 * 0.5,        # 0.5 s compute
        hbm_bytes=819e9 * 0.2,     # 0.2 s memory
        coll_bytes=50e9 * 0.8,     # 0.8 s collective
        coll_by_kind={}, chips=256,
    ).finalize()
    assert abs(r.compute_s - 0.5) < 1e-9
    assert abs(r.memory_s - 0.2) < 1e-9
    assert abs(r.collective_s - 0.8) < 1e-9
    assert r.bottleneck == "collective"
    assert r.step_time_s == r.collective_s


def test_model_flops_train_vs_decode():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    cfg = get_config("internlm2-1.8b")
    t = rl.model_flops(cfg, SHAPES["train_4k"], "train")
    d = rl.model_flops(cfg, SHAPES["decode_32k"], "decode")
    n = cfg.param_count()
    assert abs(t - 6 * n * 256 * 4096) / t < 1e-6
    assert abs(d - 2 * n * 128) / d < 1e-6


def test_cell_plan():
    from repro.launch.dryrun import cell_plan
    assert cell_plan("minitron-8b", "train_4k") == "run"
    assert cell_plan("stablelm-12b", "long_500k") == "skip"
    assert cell_plan("jamba-v0.1-52b", "long_500k") == "run"
    assert cell_plan("xlstm-125m", "long_500k") == "run"
    assert cell_plan("minitron-8b", "long_500k") == "retrieval"


def test_shape_bytes_tuple_shapes():
    assert rl._shape_bytes("(bf16[2,3]{1,0}, f32[4]{0})") == 2 * 3 * 2 + 4 * 4
    assert rl._shape_bytes("pred[7]") == 7
    assert rl._shape_bytes("token[]") == 0


FUSED_HLO = """
HloModule m
%fused_computation.1 (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %big_internal = f32[1000000]{0} broadcast(%p0)
  ROOT %r = f32[64]{0} slice(%big_internal)
}
%sum_reducer (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}
ENTRY %main (x: f32[128]) -> f32[64] {
  %x = f32[128]{0} parameter(0)
  %d = f32[128]{0} add(%x, %x)
  %f = f32[64]{0} fusion(%d), kind=kLoop, calls=%fused_computation.1
  %red = f32[] reduce(%d, %c), dimensions={0}, to_apply=%sum_reducer
  ROOT %out = f32[64]{0} multiply(%f, %f)
}
"""


def test_fused_bytes_excludes_fusion_bodies():
    got = rl.fused_bytes(FUSED_HLO)
    # add 128*4 + fusion output 64*4 + reduce 4 + multiply 64*4; the 1M-elem
    # buffer inside the fusion body and the reducer lambda must NOT count
    assert got == 128 * 4 + 64 * 4 + 4 + 64 * 4, got


def test_fused_bytes_shape_pred():
    got = rl.fused_bytes(FUSED_HLO, shape_pred=lambda dims: dims == [128])
    assert got == 128 * 4, got
