"""Benchmark utilities: wall-clock timing with warmup + synthetic data."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def timeit(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def paper_data(rng, n: int, n_classes: int = 3, d: int = 2):
    """'Randomly generated 2 dimensional data points' (paper §3)."""
    pts = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, n_classes, size=n), jnp.int32)
    return pts, labels


class Csv:
    def __init__(self, header: str):
        self.rows = [header]
        print(header, flush=True)

    def row(self, *vals):
        line = ",".join(str(v) for v in vals)
        self.rows.append(line)
        print(line, flush=True)
