"""Docs health check (run by CI and tests/test_docs.py):

  1. every RELATIVE markdown link in README.md and docs/*.md resolves to a
     real file (anchors are stripped; http(s)/mailto links are skipped);
  2. every ```python fenced code block in those files parses
     (ast.parse — the cheap end of `python -m py_compile`).

Exit code is non-zero with a per-problem listing on failure.
"""

from __future__ import annotations

import ast
import glob
import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")


def doc_files(root: str) -> list[str]:
    return [os.path.join(root, "README.md")] + sorted(
        glob.glob(os.path.join(root, "docs", "*.md"))
    )


def check_links(path: str) -> list[str]:
    problems = []
    base = os.path.dirname(os.path.abspath(path))
    with open(path) as f:
        text = f.read()
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            problems.append(f"{path}: broken link -> {target}")
    return problems


def check_code_blocks(path: str) -> list[str]:
    problems = []
    lang, block, start = None, [], 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            fence = FENCE_RE.match(line.strip())
            if fence and lang is None:
                lang, block, start = fence.group(1).lower(), [], lineno
            elif line.strip() == "```" and lang is not None:
                if lang == "python":
                    src = "".join(block)
                    try:
                        ast.parse(src)
                    except SyntaxError as e:
                        problems.append(
                            f"{path}:{start}: python block does not parse: {e}"
                        )
                lang = None
            elif lang is not None:
                block.append(line)
    return problems


def main(root: str = ".") -> int:
    problems: list[str] = []
    n_links = n_blocks = 0
    for path in doc_files(root):
        if not os.path.exists(path):
            problems.append(f"missing doc file: {path}")
            continue
        with open(path) as f:
            text = f.read()
        n_links += sum(
            1 for t in LINK_RE.findall(text)
            if not t.startswith(("http://", "https://", "mailto:", "#"))
        )
        n_blocks += len(re.findall(r"^```python", text, flags=re.M))
        problems += check_links(path)
        problems += check_code_blocks(path)
    for p in problems:
        print(f"[check_docs] {p}", file=sys.stderr)
    if problems:
        return 1
    print(f"[check_docs] OK: {len(doc_files(root))} files, "
          f"{n_links} relative links, {n_blocks} python blocks")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1] if len(sys.argv) > 1 else "."))
