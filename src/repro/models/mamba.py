"""Mamba (selective SSM) block — chunked selective scan, TPU-friendly.

The CUDA reference fuses a per-timestep recurrence into one kernel.  On TPU we
restructure (DESIGN.md §2 hardware-adaptation): an outer lax.scan over chunks
carries the (B, d_inner, d_state) SSM state, and WITHIN a chunk the linear
recurrence h_t = a_t h_{t-1} + b_t is solved with an associative scan — so the
(B, Q, d_inner, d_state) intermediate exists only per chunk, and the MXU-sized
matmuls (in/out projections) dominate.

Recurrence math (Mamba-1):
  a_t = exp(dt_t * A)          A = -exp(A_log)  (diagonal, negative)
  b_t = dt_t * B_t x_t
  y_t = C_t . h_t + D * x_t ;  out = y * silu(z)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import MambaConfig, ModelConfig
from repro.parallel.axes import constrain
from repro.utils import scan as uscan


def _dt_rank(cfg: ModelConfig) -> int:
    return cfg.mamba.dt_rank or -(-cfg.d_model // 16)


def init_mamba(key, cfg: ModelConfig) -> dict:
    mc: MambaConfig = cfg.mamba
    d = cfg.d_model
    din = mc.expand * d
    dtr = _dt_rank(cfg)
    keys = jax.random.split(key, 6)
    # dt bias: inverse-softplus of uniform [1e-3, 1e-1] (standard Mamba init)
    u = jax.random.uniform(keys[4], (din,), minval=1e-3, maxval=1e-1)
    dt_bias = jnp.log(jnp.expm1(u)).astype(jnp.float32)
    a = jnp.broadcast_to(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (din, mc.d_state))
    return {
        "in_proj": L.dense_init(keys[0], (d, 2 * din), fan_in=d),
        "conv_w": (jax.random.normal(keys[1], (mc.d_conv, din)) * 0.1).astype(jnp.float32),
        "conv_b": jnp.zeros((din,), jnp.float32),
        "x_proj": L.dense_init(keys[2], (din, dtr + 2 * mc.d_state), fan_in=din),
        "dt_proj": L.dense_init(keys[3], (dtr, din), fan_in=dtr),
        "dt_bias": dt_bias,
        "A_log": jnp.log(a),
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": L.dense_init(keys[5], (din, d), fan_in=din),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  x (B, S, din), w (dconv, din)."""
    dconv, din = w.shape
    out = lax.conv_general_dilated(
        x,
        w[:, None, :].astype(x.dtype),
        window_strides=(1,),
        padding=[(dconv - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=din,
    )
    return out + b.astype(x.dtype)


def _chunk_scan(a: jax.Array, bx: jax.Array, h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Solve h_t = a_t h_{t-1} + bx_t within one chunk, given h0.

    a, bx: (B, Q, din, ds) fp32;  h0: (B, din, ds).  Returns (h (B,Q,din,ds),
    h_last).  First-order linear recurrences are associative under
    (a1,b1)*(a2,b2) = (a1*a2, a2*b1 + b2).
    """
    bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, ar * bl + br

    _, h = lax.associative_scan(combine, (a, bx), axis=1)
    return h, h[:, -1]


def mamba_scan(
    params: dict, cfg: ModelConfig, x_in: jax.Array, h0: jax.Array, chunk: int
) -> tuple[jax.Array, jax.Array]:
    """x_in (B, S, din) post-conv activations -> (y (B, S, din), h_last)."""
    mc = cfg.mamba
    b, s, din = x_in.shape
    dtr = _dt_rank(cfg)
    xf = x_in.astype(jnp.float32)

    proj = jnp.einsum("bsd,de->bse", x_in, params["x_proj"].astype(x_in.dtype))
    dt_in, b_ssm, c_ssm = jnp.split(
        proj.astype(jnp.float32), [dtr, dtr + mc.d_state], axis=-1
    )
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"]
    )                                                              # (B, S, din)
    a_mat = -jnp.exp(params["A_log"])                              # (din, ds)

    q = min(chunk, s)
    nc = -(-s // q)
    s_pad = nc * q
    if s_pad != s:
        # identity padding: dt=0 -> a=exp(0)=1, b*x=0 (state passes through)
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        dt = jnp.pad(dt, pad)
        xf = jnp.pad(xf, pad)
        b_ssm = jnp.pad(b_ssm, pad)
        c_ssm = jnp.pad(c_ssm, pad)
    dt_c = dt.reshape(b, nc, q, din)
    xb_c = (dt * xf).reshape(b, nc, q, din)
    bs_c = b_ssm.reshape(b, nc, q, mc.d_state)
    cs_c = c_ssm.reshape(b, nc, q, mc.d_state)

    def step(h, inp):
        dt_i, xb_i, b_i, c_i = inp                                  # (B, Q, ...)
        a = jnp.exp(dt_i[..., None] * a_mat[None, None])            # (B, Q, din, ds)
        bx = xb_i[..., None] * b_i[:, :, None, :]                   # (B, Q, din, ds)
        h_all, h_last = _chunk_scan(a, bx, h)
        y = jnp.einsum("bqds,bqs->bqd", h_all, c_i)                 # (B, Q, din)
        return h_last, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (dt_c, xb_c, bs_c, cs_c))
    h_last, ys = uscan.scan(step, h0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_pad, din)[:, :s]
    y = y + xf[:, :s] * params["D"]
    return y.astype(x_in.dtype), h_last


def mamba_prefill(
    params: dict, cfg: ModelConfig, x: jax.Array
) -> tuple[jax.Array, dict]:
    """Full Mamba sublayer.  x (B, S, d) -> ((B, S, d), decode cache)."""
    mc = cfg.mamba
    b, s, _ = x.shape
    din = mc.expand * cfg.d_model
    xd = x.astype(L.ACT_DTYPE)
    xz = jnp.einsum("bsd,de->bse", xd, params["in_proj"].astype(xd.dtype))
    xz = constrain(xz, "batch", "seq", "inner")
    x_raw, z = jnp.split(xz, 2, axis=-1)
    x_in = _causal_conv(x_raw, params["conv_w"], params["conv_b"])
    x_in = jax.nn.silu(x_in.astype(jnp.float32)).astype(xd.dtype)
    h0 = jnp.zeros((b, din, mc.d_state), jnp.float32)
    y, h_last = mamba_scan(params, cfg, x_in, h0, mc.chunk)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(xd.dtype)
    y = constrain(y, "batch", "seq", "inner")
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(xd.dtype))
    cache = {"conv": x_raw[:, s - (mc.d_conv - 1) :, :].astype(L.ACT_DTYPE), "ssm": h_last}
    return out, cache


def mamba_block(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Training form (no cache)."""
    out, _ = mamba_prefill(params, cfg, x)
    return out


def init_mamba_cache(cfg: ModelConfig, batch: int) -> dict:
    mc = cfg.mamba
    din = mc.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, din), L.ACT_DTYPE),
        "ssm": jnp.zeros((batch, din, mc.d_state), jnp.float32),
    }


def mamba_decode_step(
    params: dict, cfg: ModelConfig, x: jax.Array, cache: dict
) -> tuple[jax.Array, dict]:
    """One-token decode.  x (B, 1, d); O(1) state update (no KV growth)."""
    mc = cfg.mamba
    xd = x.astype(L.ACT_DTYPE)
    xz = jnp.einsum("bsd,de->bse", xd, params["in_proj"].astype(xd.dtype))
    x_in, z = jnp.split(xz, 2, axis=-1)                             # (B, 1, din)

    # conv over [cache, x]
    window = jnp.concatenate([cache["conv"], x_in], axis=1)         # (B, dconv, din)
    w = params["conv_w"].astype(xd.dtype)                           # (dconv, din)
    xc = jnp.sum(window * w[None], axis=1, keepdims=True) + params["conv_b"].astype(xd.dtype)
    # round through bf16 exactly like the prefill path, then lift to fp32
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(L.ACT_DTYPE)
    xc = xc.astype(jnp.float32)

    dtr = _dt_rank(cfg)
    proj = jnp.einsum("bsd,de->bse", xc.astype(xd.dtype), params["x_proj"].astype(xd.dtype))
    dt_in, b_ssm, c_ssm = jnp.split(
        proj.astype(jnp.float32), [dtr, dtr + mc.d_state], axis=-1
    )
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dt_in, params["dt_proj"].astype(jnp.float32))
        + params["dt_bias"]
    )[:, 0]                                                         # (B, din)
    a_mat = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[..., None] * a_mat[None])                        # (B, din, ds)
    bx = (dt * xc[:, 0])[..., None] * b_ssm[:, 0, None, :]          # (B, din, ds)
    h = a * cache["ssm"] + bx
    y = jnp.einsum("bds,bs->bd", h, c_ssm[:, 0]) + xc[:, 0] * params["D"]
    y = (y[:, None].astype(xd.dtype)) * jax.nn.silu(z.astype(jnp.float32)).astype(xd.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(xd.dtype))
    return out, {"conv": window[:, 1:], "ssm": h}
