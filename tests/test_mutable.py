"""The mutable index subsystem (core/mutable.py + the facade's
insert/delete/snapshot): the headline invariant is that INSERT-THEN-SEARCH
is bit-identical to REBUILD-THEN-SEARCH for every registered backend, with
delete, overflow escape hatches, snapshot isolation, checkpoint round-trips,
and the online retrieval_memory / kNN-LM growth paths riding along."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.checkpoint.store import CheckpointManager
from repro.core import knn_lm
from repro.core import mutable as mut
from repro.core import retrieval_memory as rmem
from repro.core.grid import GridConfig, build_index, validate_invariants
from repro.core.projection import identity_projection

CFG = GridConfig(grid_size=128, tile=16, n_classes=3, window=48, row_cap=48,
                 r0=8, k_slack=2.0)


def _data(rng, n, scale=1.0, d=2):
    pts = jnp.asarray(rng.normal(size=(n, d)) * scale, jnp.float32)
    labels = jnp.asarray(rng.integers(0, 3, size=n), jnp.int32)
    return pts, labels


def _assert_index_equal(a, b):
    for f in ("points_sorted", "coords_sorted", "labels_sorted",
              "ids_sorted", "offsets"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
        )
    assert len(a.pyramid) == len(b.pyramid)
    for lv, (pa, pb) in enumerate(zip(a.pyramid, b.pyramid)):
        np.testing.assert_array_equal(
            np.asarray(pa), np.asarray(pb), err_msg=f"pyramid[{lv}]"
        )
    assert (a.pyr_tiles is None) == (b.pyr_tiles is None)
    if a.pyr_tiles is not None:
        np.testing.assert_array_equal(
            np.asarray(a.pyr_tiles), np.asarray(b.pyr_tiles), err_msg="pyr_tiles"
        )


def _assert_results_equal(a, b, msg=""):
    for field in api.SearchResult._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
            err_msg=f"{msg}:{field}",
        )


# ------------------------------------------------------------ core parity ----


def test_insert_snapshot_bit_identical_to_rebuild(rng):
    """snapshot(insert(from_index(build(P1)), P2)) == build(P1 u P2) on every
    array of the index — CSR order, offsets, pyramid, flattened tiles."""
    pts, labels = _data(rng, 2500)
    proj = identity_projection(pts)
    n1 = 2000
    full = build_index(pts, CFG, proj, labels=labels)
    state = mut.from_index(build_index(pts[:n1], CFG, proj, labels=labels[:n1]), CFG)
    state = mut.insert(state, CFG, pts[n1:], labels=labels[n1:])
    _assert_index_equal(full, mut.snapshot(state, CFG))
    assert all(mut.validate_mutable(state, CFG).values())


def test_delete_bit_identical_to_rebuild_of_survivors(rng):
    pts, labels = _data(rng, 1500)
    proj = identity_projection(pts)
    state = mut.from_index(build_index(pts, CFG, proj, labels=labels), CFG)
    del_ids = jnp.asarray(rng.choice(1500, size=400, replace=False), jnp.int32)
    state = mut.delete(state, CFG, del_ids)
    keep = np.setdiff1d(np.arange(1500), np.asarray(del_ids))
    ref = build_index(pts[keep], CFG, proj, labels=labels[keep],
                      ids=jnp.asarray(keep, jnp.int32))
    _assert_index_equal(ref, mut.snapshot(state, CFG))


def test_interleaved_insert_delete_parity(rng):
    """Multiple rounds of mixed mutation stay bit-identical to a one-shot
    build of the surviving points, starting from an EMPTY index."""
    pts, labels = _data(rng, 900)
    proj = identity_projection(pts)
    empty = build_index(jnp.zeros((0, 2), jnp.float32), CFG, proj,
                        labels=jnp.zeros((0,), jnp.int32))
    state = mut.from_index(empty, CFG)
    state = mut.insert(state, CFG, pts[:300], labels=labels[:300])
    state = mut.insert(state, CFG, pts[300:700], labels=labels[300:700])
    state = mut.delete(state, CFG, jnp.arange(100, 250, dtype=jnp.int32))
    state = mut.insert(state, CFG, pts[700:], labels=labels[700:])
    keep = np.r_[0:100, 250:900]
    ref = build_index(pts[keep], CFG, proj, labels=labels[keep],
                      ids=jnp.asarray(keep, jnp.int32))
    _assert_index_equal(ref, mut.snapshot(state, CFG))
    inv = validate_invariants(mut.snapshot(state, CFG), CFG)
    assert all(inv.values()), inv


def test_facade_insert_search_parity_all_backends(rng):
    """The acceptance invariant: build(P1).insert(P2).search(Q) equals
    build(P1 u P2).search(Q) — ids, distances, AND the Eq.-1 stat fields —
    for EVERY registered backend that can search.  Mesh-requiring backends
    (sharded) run the same matrix on build_sharded handles over however
    many devices the process sees (8 under the CI multi-device job)."""
    from repro.core import distributed as D

    pts, labels = _data(rng, 1200)
    proj = identity_projection(pts)
    n1 = 900
    s1 = api.ActiveSearcher.from_index(
        build_index(pts[:n1], CFG, proj, labels=labels[:n1]), CFG
    )
    grown = s1.insert(pts[n1:], labels=labels[n1:])
    ref = api.ActiveSearcher.from_index(
        build_index(pts, CFG, proj, labels=labels), CFG
    )
    q = jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))
    sh_grown = api.ActiveSearcher.build_sharded(
        pts[:n1], mesh=mesh, axis="data", labels=labels[:n1], cfg=CFG,
        proj=proj,
    ).insert(pts[n1:], labels=labels[n1:])
    sh_ref = api.ActiveSearcher.build_sharded(
        pts, mesh=mesh, axis="data", labels=labels, cfg=CFG, proj=proj)
    # the sweep must include the quantized backend: its store is DERIVED
    # from the snapshot, so insert == rebuild has to survive requantization
    assert "pallas_q8" in api.registered_backends()
    for name in api.registered_backends():
        impl = api.get_backend(name)
        if impl.search is None:
            continue
        if impl.requires_mesh:
            a_h, b_h = sh_grown.with_plan(backend=name), \
                sh_ref.with_plan(backend=name)
            qq = D.replicate_queries(q, mesh)
        else:
            a_h, b_h, qq = grown.with_plan(backend=name), \
                ref.with_plan(backend=name), q
        _assert_results_equal(a_h.search(qq, 8), b_h.search(qq, 8), msg=name)
        np.testing.assert_array_equal(
            np.asarray(a_h.classify(qq, 8)),
            np.asarray(b_h.classify(qq, 8)),
            err_msg=name,
        )
        if impl.supports_adaptive_r0:
            # adaptive seeding reads the pyramid's TOP levels, which delta
            # updates must keep consistent — grown vs rebuilt must agree on
            # the full adaptive schedule too
            a = a_h.with_plan(backend=name, adaptive_r0=True).search(qq, 8)
            b = b_h.with_plan(backend=name, adaptive_r0=True).search(qq, 8)
            _assert_results_equal(a, b, msg=f"{name}:adaptive_r0")


def test_facade_delete_then_exact_backend_forgets_points(rng):
    """Deleted points are gone from every backend, including the exact
    comparator whose memoized original-order cache must NOT survive the
    mutation (the returned handle is a new object with a cold cache)."""
    pts, labels = _data(rng, 600)
    proj = identity_projection(pts)
    s = api.ActiveSearcher.from_index(
        build_index(pts, CFG, proj, labels=labels), CFG,
        plan=api.ExecutionPlan(backend="exact"),
    )
    q = pts[:4]
    before = s.search(q, 1)  # also warms the exact-order memo on s
    assert "_exact_ordered_cache" in s.__dict__
    np.testing.assert_array_equal(np.asarray(before.ids[:, 0]),
                                  np.arange(4))
    s2 = s.delete(jnp.arange(4, dtype=jnp.int32))
    assert "_exact_ordered_cache" not in s2.__dict__
    after = s2.search(q, 1)
    assert not np.intersect1d(np.asarray(after.ids), np.arange(4)).size
    # the source handle still sees the original contents
    _assert_results_equal(before, s.search(q, 1))


# ------------------------------------------------------- slack management ----


def test_spill_overflow_raises_or_compacts(rng):
    pts, _ = _data(rng, 500)
    far = jnp.asarray(rng.normal(size=(64, 2)) * 3, jnp.float32)  # fresh cells
    proj = identity_projection(jnp.concatenate([pts, far]))
    index = build_index(pts, CFG, proj)
    state = mut.from_index(index, CFG, spill_capacity=4)
    with pytest.raises(mut.BucketOverflow, match="spill slots"):
        mut.insert(state, CFG, far, on_overflow="raise")
    grown = mut.insert(state, CFG, far)  # default: compact + retry
    ref = build_index(jnp.concatenate([pts, far]), CFG, proj)
    _assert_index_equal(ref, mut.snapshot(grown, CFG))


def test_overflow_compact_retry_survives_slack_retightening(rng):
    """compact() shrinks bucket slack, so points that FIT the old layout can
    spill in the fresh one — the retry's spill capacity must cover the whole
    batch, not just the pre-compact spill count (regression)."""
    # one crowded cell: lots of tombstone slack that compact reclaims
    pts = jnp.zeros((100, 2), jnp.float32) + 0.5
    far = jnp.asarray(rng.normal(size=(8, 2)) * 3 + 10, jnp.float32)
    proj = identity_projection(jnp.concatenate([pts, far]))
    state = mut.from_index(build_index(pts, CFG, proj), CFG, spill_capacity=4)
    state = mut.delete(state, CFG, jnp.arange(90, dtype=jnp.int32))
    # 40 points into the crowded cell (fit pre-compact slack) + 8 into fresh
    # cells (must spill; 8 > spill_capacity=4 forces the compact retry)
    batch = jnp.concatenate([jnp.zeros((40, 2), jnp.float32) + 0.5, far])
    grown = mut.insert(state, CFG, batch)  # must not raise
    assert int(grown.n_live) == 10 + 48
    keep_ids = np.r_[90:100, 100:148]
    snap = mut.snapshot(grown, CFG)
    assert set(np.asarray(snap.ids_sorted).tolist()) == set(keep_ids.tolist())
    assert all(validate_invariants(snap, CFG).values())


def test_compact_preserves_contents_and_frees_slack(rng):
    pts, labels = _data(rng, 800)
    proj = identity_projection(pts)
    state = mut.from_index(build_index(pts, CFG, proj, labels=labels), CFG)
    state = mut.delete(state, CFG, jnp.arange(0, 200, dtype=jnp.int32))
    packed = mut.compact(state, CFG)
    _assert_index_equal(mut.snapshot(state, CFG), mut.snapshot(packed, CFG))
    assert int(packed.spill_used) == 0
    assert all(mut.validate_mutable(packed, CFG).values())
    # compact must not recycle deleted ids for later auto-assigned inserts
    assert int(packed.next_id) == int(state.next_id)


def test_rebuild_escape_hatch_matches_compact(rng):
    pts, labels = _data(rng, 600)
    proj = identity_projection(pts)
    state = mut.from_index(build_index(pts, CFG, proj, labels=labels), CFG)
    state = mut.insert(state, CFG, pts[:50] + 0.01, labels=labels[:50])
    _assert_index_equal(
        mut.snapshot(mut.compact(state, CFG), CFG),
        mut.snapshot(mut.rebuild(state, CFG), CFG),
    )


def test_delete_unknown_id_strict_vs_lenient(rng):
    pts, _ = _data(rng, 100)
    state = mut.from_index(build_index(pts, CFG, identity_projection(pts)), CFG)
    with pytest.raises(KeyError, match="not live"):
        mut.delete(state, CFG, jnp.asarray([5, 9999], jnp.int32))
    ok = mut.delete(state, CFG, jnp.asarray([5, 9999], jnp.int32), strict=False)
    assert int(ok.n_live) == 99


# --------------------------------------------------- invariants + isolation --


def test_validate_invariants_on_mutated_index(rng):
    """The extended invariant set (CSR sortedness, base==offsets, pyramid
    chain, tile re-flattening) holds on a heavily mutated snapshot — and the
    tile check actually FAILS on a corrupted tile array."""
    pts, labels = _data(rng, 1000)
    proj = identity_projection(pts)
    state = mut.from_index(build_index(pts, CFG, proj, labels=labels), CFG)
    state = mut.insert(state, CFG, pts[:200] * 0.5, labels=labels[:200])
    state = mut.delete(state, CFG, jnp.arange(50, 350, dtype=jnp.int32))
    snap = mut.snapshot(state, CFG)
    inv = validate_invariants(snap, CFG)
    assert all(inv.values()), inv
    bad = snap._replace(pyr_tiles=snap.pyr_tiles.at[0, 0, 0, 0].add(7))
    assert not validate_invariants(bad, CFG)["tiles_match_pyramid"]
    bad2 = snap._replace(
        pyramid=(snap.pyramid[0],) + tuple(
            p.at[0, 0, 0].add(1) for p in snap.pyramid[1:]
        )
    )
    assert not validate_invariants(bad2, CFG)["pyramid_chain_consistent"]


def test_snapshot_isolation_under_concurrent_mutation(rng):
    """A snapshot handle keeps serving the SAME results while the source
    handle keeps inserting/deleting (arrays are immutable; delta updates
    build new ones) — the mid-search corruption case from the issue."""
    pts, labels = _data(rng, 800)
    proj = identity_projection(jnp.concatenate([pts, pts * 2]))
    s = api.ActiveSearcher.from_index(
        build_index(pts, CFG, proj, labels=labels), CFG
    )
    q = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)
    frozen = s.snapshot()
    want = frozen.search(q, 8)
    live = s
    for step in range(3):
        live = live.insert(pts[:100] * (1.1 + step), labels=labels[:100])
        live = live.delete(live.index.ids_sorted[:10])
        _assert_results_equal(want, frozen.search(q, 8), msg=f"step{step}")
    assert live.index.n_points == 800 + 3 * 100 - 3 * 10


def test_snapshot_state_decoupled_from_source(rng):
    """insert on a snapshot() handle does not advance the source's slack
    state, and vice versa."""
    pts, _ = _data(rng, 300)
    s = api.ActiveSearcher.from_index(
        build_index(pts, CFG, identity_projection(pts)), CFG
    )
    a = s.insert(pts[:10] + 0.01)
    frozen = a.snapshot()
    assert frozen.stats()["mutable"] is False and a.stats()["mutable"] is True
    b = frozen.insert(pts[:5] + 0.02)
    assert b.index.n_points == 315 and a.index.n_points == 310


# -------------------------------------------------------------- consumers ----


def test_retrieval_memory_online_extension_parity(rng):
    cfg = rmem.RetrievalMemoryConfig(n_retrieved=8)
    proj = rmem.make_projection(jax.random.PRNGKey(0), head_dim=16)
    keys = jnp.asarray(rng.normal(size=(512, 16)) * 0.3, jnp.float32)
    full = rmem.build_memory_index(keys, cfg, proj)
    grown = rmem.extend_memory_index(
        rmem.build_memory_index(keys[:384], cfg, proj), cfg, keys[384:]
    )
    _assert_index_equal(full, grown)
    # a query near a NEW key retrieves its (appended) position
    pos, ok = rmem.retrieve_positions(grown, cfg, keys[500:502])
    assert bool(ok.any()) and 500 in np.asarray(pos[0])


def test_knn_lm_datastore_online_extension(rng):
    cfg = knn_lm.KNNLMConfig(k=4)
    keys, _ = _data(rng, 400, d=8)
    toks = jnp.asarray(rng.integers(0, 32, size=400), jnp.int32)
    full = knn_lm.build_datastore(keys, toks, cfg)
    part = knn_lm.build_datastore(keys[:300], toks[:300], cfg,
                                  proj=full.proj)
    grown = knn_lm.extend_datastore(part, cfg, keys[300:], toks[300:])
    _assert_index_equal(full, grown)
    lp_full = knn_lm.knn_logprobs(full, cfg, keys[:6], 32)
    lp_grown = knn_lm.knn_logprobs(grown, cfg, keys[:6], 32)
    np.testing.assert_array_equal(np.asarray(lp_full), np.asarray(lp_grown))


def test_checkpoint_roundtrip_mutable_state(rng, tmp_path):
    """save_mutable_index/restore_mutable_index preserve the FULL mutation
    state — the restored index keeps accepting deltas and stays
    bit-identical to the never-persisted one."""
    pts, labels = _data(rng, 600)
    proj = identity_projection(pts)
    state = mut.from_index(build_index(pts, CFG, proj, labels=labels), CFG)
    state = mut.insert(state, CFG, pts[:80] * 0.9, labels=labels[:80])
    state = mut.delete(state, CFG, jnp.arange(10, dtype=jnp.int32))

    mgr = CheckpointManager(str(tmp_path))
    mgr.save_mutable_index(3, state, blocking=True)
    mgr.wait()
    back = mgr.restore_mutable_index(3)
    _assert_index_equal(mut.snapshot(state, CFG), mut.snapshot(back, CFG))
    more = pts[100:150] * 1.05
    _assert_index_equal(
        mut.snapshot(mut.insert(state, CFG, more), CFG),
        mut.snapshot(mut.insert(back, CFG, more), CFG),
    )


# ------------------------------------------------------------------- edges ---


def test_insert_empty_batch_and_custom_ids(rng):
    pts, _ = _data(rng, 200)
    cfg = GridConfig(grid_size=64, tile=8, window=16, row_cap=32, r0=4,
                     k_slack=2.0)
    state = mut.from_index(build_index(pts, cfg, identity_projection(pts)), cfg)
    assert mut.insert(state, cfg, jnp.zeros((0, 2), jnp.float32)) is state
    grown = mut.insert(state, cfg, pts[:3] + 0.01,
                       ids=jnp.asarray([500, 700, 600], jnp.int32))
    assert int(grown.next_id) == 701
    snap = mut.snapshot(grown, cfg)
    assert {500, 600, 700} <= set(np.asarray(snap.ids_sorted).tolist())


def test_delete_with_colliding_ids_kills_every_carrier(rng):
    """Records are keyed by id: if a caller inserts a duplicate of a live id,
    delete(id) removes BOTH carriers and the strict check counts matched IDS
    (not slots), so it neither rejects the delete nor reports a negative
    missing count (regression)."""
    pts, _ = _data(rng, 100)
    cfg = GridConfig(grid_size=64, tile=8, window=16, row_cap=32, r0=4,
                     k_slack=2.0)
    state = mut.from_index(build_index(pts, cfg, identity_projection(pts)), cfg)
    state = mut.insert(state, cfg, pts[5:6] + 0.01,
                       ids=jnp.asarray([5], jnp.int32))
    state = mut.delete(state, cfg, jnp.asarray([5], jnp.int32))
    assert int(state.n_live) == 99  # 101 - both carriers of id 5
    assert 5 not in np.asarray(mut.snapshot(state, cfg).ids_sorted).tolist()
    assert all(mut.validate_mutable(state, cfg).values())


def test_insert_batch_sizes_share_jit_shapes(rng):
    """pow2 padding: batches of 5 and 7 run through the same padded kernel
    shape and still produce rebuild-identical contents."""
    pts, _ = _data(rng, 300)
    cfg = GridConfig(grid_size=64, tile=8, window=16, row_cap=32, r0=4,
                     k_slack=2.0)
    proj = identity_projection(pts)
    state = mut.from_index(build_index(pts[:288], cfg, proj), cfg)
    state = mut.insert(state, cfg, pts[288:293])   # 5 -> padded to 8
    state = mut.insert(state, cfg, pts[293:300])   # 7 -> same padded shape
    _assert_index_equal(build_index(pts, cfg, proj),
                        mut.snapshot(state, cfg))


def test_mutable_with_sat_counter(rng):
    cfg = GridConfig(grid_size=64, tile=8, window=16, row_cap=32, r0=4,
                     k_slack=2.0, counter="sat")
    pts, _ = _data(rng, 400)
    proj = identity_projection(pts)
    state = mut.from_index(build_index(pts[:300], cfg, proj), cfg)
    state = mut.insert(state, cfg, pts[300:])
    ref = build_index(pts, cfg, proj)
    snap = mut.snapshot(state, cfg)
    np.testing.assert_array_equal(np.asarray(ref.sat), np.asarray(snap.sat))
    assert snap.pyr_tiles is None


def test_mutation_rejected_by_capability_not_name(rng):
    """Eager validation is capability-driven: a backend registered WITHOUT
    `supports_mutation` rejects insert/delete with the capability named in
    the message, before any state is opened — same PR-3 style as the
    interpret/d_chunk plan validation."""
    pts, labels = _data(rng, 64)
    cfg = GridConfig(grid_size=32, tile=8, window=8, row_cap=16, r0=4)
    s = api.ActiveSearcher.from_index(
        build_index(pts, cfg, identity_projection(pts), labels=labels), cfg
    ).with_plan(backend="pallas_stacked")
    assert not api.get_backend("pallas_stacked").supports_mutation
    with pytest.raises(ValueError, match="supports_mutation"):
        s.insert(pts[:2])
    with pytest.raises(ValueError, match="supports_mutation"):
        s.delete(jnp.asarray([0], jnp.int32))
    # the error lists the capable backends, so the fix is in the message
    with pytest.raises(ValueError, match="sharded"):
        s.insert(pts[:2])


def test_sharded_merge_tiebreak_pinned_to_global_id(rng):
    """Regression pin for the global top-k merge: distance ties order by
    GLOBAL id (lax.sort num_keys=2), not by shard/CSR position — the full
    multi-shard version lives in tests/test_sharded_mutable.py."""
    cfg = GridConfig(grid_size=32, tile=8, window=16, row_cap=16, r0=4)
    pts = jnp.asarray([[0.5, 0.0], [-0.5, 0.0], [4.0, 4.0], [-4.0, -4.0]],
                      jnp.float32)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("data",))
    s = api.ActiveSearcher.build_sharded(
        pts, mesh=mesh, axis="data", cfg=cfg,
        proj=identity_projection(pts),
        ids=jnp.asarray([3, 7, 11, 12], jnp.int32),  # CSR order is 7 then 3
    )
    from repro.core import distributed as D

    res = s.search(D.replicate_queries(jnp.zeros((1, 2), jnp.float32), mesh), 2)
    d = np.asarray(res.dists[0])
    assert d[0] == d[1], d
    np.testing.assert_array_equal(np.asarray(res.ids[0]), [3, 7])
