"""Production meshes (functions, not constants — importing this module never
touches jax device state).

Single pod:  (16, 16)    axes ('data', 'model')   = 256 chips (one v5e pod)
Multi pod:   (2, 16, 16) axes ('pod', 'data', 'model') = 512 chips

'pod' composes with 'data' for batch sharding (pure DP across pods — the only
axis that crosses the slower inter-pod links; gradient all-reduce over it is
the one cross-pod collective, optionally int8-compressed).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)"
        )
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    devices = jax.devices()
    n = data * model
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(data, model), ("data", "model"))


def mesh_chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
