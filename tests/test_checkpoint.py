"""CheckpointManager: roundtrip, async, GC, atomicity, reshape guards."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "opt": [jnp.ones((3,)), jnp.int32(7)],
        "step": jnp.int32(42),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(10, tree, blocking=True)
    got = mgr.restore(10, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.latest_step() == 4
    assert mgr.list_steps() == [3, 4]  # keep=2


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5


def test_no_tmp_dirs_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
    assert not leftovers


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=True)
    bad = _tree()
    bad["params"]["w"] = jnp.zeros((9, 16))
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore(1, jax.eval_shape(lambda: bad))


def test_restore_applies_shardings(tmp_path):
    """restore(shardings=...) lands leaves with the requested sharding —
    the elastic reshard-on-load path (mesh B may differ from mesh A)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh

    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(2, tree, blocking=True)
    mesh = make_host_mesh(1, 1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    got = mgr.restore(2, jax.eval_shape(lambda: tree), shardings=sh)
    for leaf in jax.tree.leaves(got):
        assert leaf.sharding == NamedSharding(mesh, P())
