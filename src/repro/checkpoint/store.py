"""Sharded checkpointing: npz-per-leaf-group + JSON manifest, async writes,
atomic renames, elastic reshard-on-load.

Layout:  <dir>/step_<k>/manifest.json + arrays.npz  (tmp dir + rename = atomic)
Restore onto ANY mesh: arrays are loaded host-side and device_put with the
TARGET sharding — train on mesh A, resume on mesh B (elastic scaling test).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _treedef_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending: Future | None = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------- save ----

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot to host memory NOW; write in the background (async)."""
        flat = _flatten(tree)  # device_get happens here, synchronously

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            manifest = {
                "step": step,
                "keys": sorted(flat),
                "shapes": {k: list(v.shape) for k, v in flat.items()},
                "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        with self._lock:
            if self._pending is not None:
                self._pending.result()  # one in flight at a time
            self._pending = self._pool.submit(write)
            if blocking:
                self._pending.result()

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ---------------------------------------------------------- restore ----

    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore_arrays(self, step: int) -> dict[str, np.ndarray]:
        """Raw {key: array} contents of a step — no structure donor needed.

        This is the restore path for states whose SHAPES are not known up
        front (e.g. a mutable grid index whose slack layout grew since the
        code was written): the caller reconstructs the object from names."""
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            return {k: z[k] for k in z.files}

    def save_mutable_index(self, step: int, state: Any,
                           blocking: bool = False) -> None:
        """Persist a `core.mutable.MutableIndex` (slack layout, spill log,
        pyramid, tiles — everything needed to keep mutating after restart)."""
        from repro.core import mutable as mut

        self.save(step, mut.state_to_tree(state), blocking=blocking)

    def restore_mutable_index(self, step: int) -> Any:
        """Inverse of `save_mutable_index` — shape-free (see restore_arrays)."""
        from repro.core import mutable as mut

        return mut.state_from_tree(self.restore_arrays(step))

    def restore(self, step: int, like: Any, shardings: Any | None = None) -> Any:
        """Rebuild the pytree of `like` (structure donor).  If `shardings`
        (same structure) is given, leaves are device_put with it — this is the
        elastic reshard path: the target mesh can differ from the saved one."""
        path = os.path.join(self.dir, f"step_{step}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}

        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
        treedef = _treedef_of(like)
        new_leaves = []
        for p, leaf in leaves_with_path:
            key = _SEP.join(
                str(getattr(q, "key", getattr(q, "idx", getattr(q, "name", q)))) for q in p
            )
            arr = flat[key]
            expect = tuple(leaf.shape)
            if tuple(arr.shape) != expect:
                raise ValueError(f"checkpoint shape mismatch at {key}: {arr.shape} vs {expect}")
            new_leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree
