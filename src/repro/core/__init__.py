"""Core: the paper's active-search kNN as a composable JAX library.

Public entry point: `repro.api` (`core/engine.py`) — one `ActiveSearcher`
handle over every execution backend, planned by a frozen `ExecutionPlan`.
The module-level `search`/`classify` here are deprecation shims kept for
older call sites.
"""

from repro.core.grid import GridConfig, GridIndex, build_index
from repro.core.projection import (
    Projection,
    gaussian_projection,
    identity_projection,
    pca_projection,
)
from repro.core.active_search import SearchResult, classify, search, search_one
from repro.core.engine import (
    ActiveSearcher,
    BackendImpl,
    ExecutionPlan,
    get_backend,
    register_backend,
    registered_backends,
)
from repro.core import exact

__all__ = [
    "GridConfig",
    "GridIndex",
    "build_index",
    "Projection",
    "identity_projection",
    "gaussian_projection",
    "pca_projection",
    "SearchResult",
    "search",
    "search_one",
    "classify",
    "exact",
    "ActiveSearcher",
    "BackendImpl",
    "ExecutionPlan",
    "get_backend",
    "register_backend",
    "registered_backends",
]
