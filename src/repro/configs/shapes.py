"""Assigned input shapes and their ShapeDtypeStruct stand-ins.

Four shapes per LM architecture (40 cells total):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill_step
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 token, full cache)
  long_500k    seq 524,288 global_batch 1     -> serve_step; needs sub-quadratic
               attention: native for ssm/hybrid, active-search retrieval memory
               for the beyond-paper cells, SKIP for pure full-attention archs.

input_specs() returns weak-type-correct ShapeDtypeStructs only — no device
allocation ever happens for the full configs (dry-run contract).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Model-input ShapeDtypeStructs for train/prefill (tokens + frontends)."""
    b, s = shape.global_batch, shape.seq_len
    specs: dict = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    if cfg.frontend == "audio":
        # EnCodec frame embeddings arrive precomputed (assignment: frontend stub)
        specs["frame_embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision":
        specs["vision_embeds"] = _sds(
            (b, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """serve_step inputs: one new token against a seq_len cache/state."""
    b, s = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(lambda: M.init_caches(cfg, b, s))
    return {
        "caches": caches,
        "token": _sds((b,), jnp.int32),
        "pos": _sds((), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return decode_specs(cfg, shape)
    return batch_specs(cfg, shape)
