"""Pallas TPU kernel: circle-masked tile count (the paper's hot loop).

The paper's per-iteration cost is "checking all the inner pixels of the
current circle" (§3).  On TPU that becomes: DMA ONE fixed-size window of a
pyramid level from HBM into VMEM, apply the circular mask against cell
centers on the VPU, and reduce.  The window is data-dependent (it saccades to
the query), which we express with scalar-prefetched block origins driving the
BlockSpec index_map: the same level array is passed four times with index
maps (bx0+di, by0+dj), di,dj in {0,1}, so the four T-aligned tiles cover any
un-aligned T-window.

Layout notes for the v5e target: T should be a multiple of 8 (sublanes) and
the channel dim is kept innermost; with C=1..8 the (T, T, C) tile stays well
under VMEM (T=128, C=4, int32 -> 256 KiB per tile).  Validated on CPU with
interpret=True against ref.tile_count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def circle_window_sum(
    vals,   # (T, T, C) int32 — one cover tile's counts
    bx, by,  # int32 — the tile's block coords (level-cell index / T)
    qx, qy, r, scale,  # query position, radius (base px), 2**level
    oxf, oyf,  # float32 — clamped window origin in level cells
    zero,   # bool — duplicate-cover tile, contribute nothing
    *,
    tile: int,
    metric: str,
):
    """Per-class sum of `vals` over cells inside the circle AND the clamped
    [ox, ox+T) x [oy, oy+T) reference window.

    The single shared definition of the counting contract (both count
    kernels call it), bit-for-bit with `pyramid._count_at_level`: the
    window mask keeps circles that overrun the window from reaching cells
    the oracle never scans, and `zero` blanks aliased duplicate tiles of
    the 2x2 block cover.  `scale` may be a static int (single-level) or a
    prefetched float32 scalar (level-scheduled).
    """
    ii = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 0)
    jj = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 1)
    tf = jnp.float32(tile)
    gx = (bx * tile).astype(jnp.float32) + ii  # global level-cell index
    gy = (by * tile).astype(jnp.float32) + jj
    ci = (gx + 0.5) * scale                    # cell center, base px
    cj = (gy + 0.5) * scale
    if metric == "l1":
        inside = (jnp.abs(ci - qx) + jnp.abs(cj - qy)) <= r
    else:
        inside = (ci - qx) ** 2 + (cj - qy) ** 2 <= r * r
    window = (gx >= oxf) & (gx < oxf + tf) & (gy >= oyf) & (gy < oyf + tf)
    inside = jnp.logical_and(inside & window, jnp.logical_not(zero))
    return jnp.sum(vals * inside[:, :, None].astype(jnp.int32), axis=(0, 1))


def _kernel(
    origins_ref,  # scalar prefetch: (B, 4) int32 (bx0, by0, ox, oy) —
                  # block origins + clamped window origin in level cells
    q_ref,        # scalar prefetch: (B, 2) float32 query positions (base px)
    r_ref,        # scalar prefetch: (B,) float32 radii (base px)
    t00, t01, t10, t11,  # (T, T, C) int32 tiles
    out_ref,      # (1, C) int32
    *,
    tile: int,
    scale: int,
    nblk: int,
    metric: str,
):
    b = pl.program_id(0)
    bx0 = origins_ref[b, 0]
    by0 = origins_ref[b, 1]
    oxf = origins_ref[b, 2].astype(jnp.float32)
    oyf = origins_ref[b, 3].astype(jnp.float32)
    qx = q_ref[b, 0]
    qy = q_ref[b, 1]
    r = r_ref[b]

    # duplicate-tile guards: when bx0+1 is clamped by the index_map the
    # di=1 tiles alias the di=0 tiles and must contribute zero.
    dup_x = (bx0 + 1) > (nblk - 1)
    dup_y = (by0 + 1) > (nblk - 1)

    def masked_sum(t_ref, bx, by, zero):
        return circle_window_sum(
            t_ref[...], bx, by, qx, qy, r, scale, oxf, oyf, zero,
            tile=tile, metric=metric,
        )

    bx1 = jnp.minimum(bx0 + 1, nblk - 1)
    by1 = jnp.minimum(by0 + 1, nblk - 1)
    total = (
        masked_sum(t00, bx0, by0, False)
        + masked_sum(t01, bx0, by1, dup_y)
        + masked_sum(t10, bx1, by0, dup_x)
        + masked_sum(t11, bx1, by1, jnp.logical_or(dup_x, dup_y))
    )
    out_ref[0, :] = total


@functools.partial(
    jax.jit, static_argnames=("scale", "tile", "metric", "interpret")
)
def tile_count(
    level_arr: jax.Array,
    queries: jax.Array,
    radii: jax.Array,
    scale: int,
    tile: int,
    metric: str = "l2",
    interpret: bool = True,
) -> jax.Array:
    """Circle-masked counts (B, C) from one pyramid level (S, S, C).

    Contract identical to ref.tile_count (which mirrors
    pyramid._count_at_level) for EVERY radius: cells outside the clamped
    [ox, ox+T) x [oy, oy+T) reference window are masked out, so the kernel
    stays bit-for-bit with the oracle even when the circle overruns the
    window (radius clamped at the top level, grid-edge queries).
    """
    s, _, c = level_arr.shape
    if s % tile:
        raise ValueError(f"level size {s} must be a multiple of tile {tile}")
    nblk = s // tile
    b = queries.shape[0]

    q = queries.astype(jnp.float32)
    r = radii.astype(jnp.float32)
    cx = jnp.floor(q[:, 0] / scale).astype(jnp.int32)
    cy = jnp.floor(q[:, 1] / scale).astype(jnp.int32)
    ox = jnp.clip(cx - tile // 2, 0, s - tile)
    oy = jnp.clip(cy - tile // 2, 0, s - tile)
    # (B, 4): T-aligned block origin (drives the index_map) + exact window
    # origin (drives the in-kernel window-parity mask)
    origins = jnp.stack([ox // tile, oy // tile, ox, oy], axis=1)

    def im(di, dj):
        def index_map(i, origins_ref, q_ref, r_ref):
            bx = jnp.minimum(origins_ref[i, 0] + di, nblk - 1)
            by = jnp.minimum(origins_ref[i, 1] + dj, nblk - 1)
            return bx, by, 0

        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((tile, tile, c), im(0, 0)),
            pl.BlockSpec((tile, tile, c), im(0, 1)),
            pl.BlockSpec((tile, tile, c), im(1, 0)),
            pl.BlockSpec((tile, tile, c), im(1, 1)),
        ],
        out_specs=pl.BlockSpec((1, c), lambda i, *_: (i, 0)),
    )
    kernel = functools.partial(
        _kernel, tile=tile, scale=scale, nblk=nblk, metric=metric
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        interpret=interpret,
    )(origins, q, r, level_arr, level_arr, level_arr, level_arr)
