"""End-to-end training driver: a ~100M-param decoder LM for a few hundred
steps on the synthetic bigram corpus, with checkpointing + fault tolerance.

  PYTHONPATH=src python examples/train_lm.py                 # ~100M, 300 steps
  PYTHONPATH=src python examples/train_lm.py --fast          # 2-minute demo

The loop is the production one (repro.launch.train): sharded step, async
checkpoints every 50 steps, SIGTERM-safe, restart-from-checkpoint supervisor.
On a TPU pod the same script runs with --data/--model mesh axes.
"""

import argparse
import dataclasses

from repro.configs import get_smoke
from repro.launch.mesh import make_host_mesh
from repro.launch.train import TrainConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="tiny 2-minute demo")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_smoke("internlm2-1.8b")
    if args.fast:
        cfg = base                                      # ~1M params
        tc = TrainConfig(steps=args.steps or 60, batch=8, seq=128,
                         ckpt_dir=args.ckpt_dir, ckpt_every=25, log_every=10)
    else:
        # ~100M params: 12L x d768 x ff3072, vocab 8192
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, head_dim=96, d_ff=3072,
            vocab_size=8192,
        )
        tc = TrainConfig(steps=args.steps or 300, batch=8, seq=256,
                         ckpt_dir=args.ckpt_dir, ckpt_every=50, log_every=10)

    n_params = cfg.param_count()
    print(f"[example] arch={cfg.name} params~{n_params/1e6:.1f}M "
          f"steps={tc.steps} global_batch={tc.batch}x{tc.seq}")
    mesh = make_host_mesh(1, 1)
    out = run(cfg, tc, mesh)
    print(f"[example] loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"over {out['final_step']} steps; stragglers={out['stragglers']}")
    assert out["losses"][-1] < out["losses"][0]


if __name__ == "__main__":
    main()
