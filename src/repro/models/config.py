"""Model/architecture configuration — one frozen dataclass per assigned arch.

The same decoder composition serves all 10 assigned architectures via a
per-layer `block_pattern` ("attn" | "mamba" | "mlstm" | "slstm"), an optional
MoE config, and an optional modality frontend stub (audio/vlm per assignment).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "mamba", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                # routed experts (may be padded for EP divisibility)
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_padded: int = 0             # experts added for model-axis divisibility (never routed)
    shared_d_ff: int = 0          # shared-expert MLP hidden size (0 = none)
    every_n: int = 1              # MoE every n-th layer (others dense MLP)
    capacity_factor: float = 1.25
    group_size: int = 2048        # GShard dispatch group size (tokens)

    @property
    def n_total(self) -> int:
        return self.n_experts + self.n_padded


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)
    chunk: int = 256              # chunked selective-scan block length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3334
    n_heads: int = 4
    chunk: int = 256              # mLSTM chunkwise-parallel block length
    slstm_every: int = 6          # one sLSTM block per this many layers
    slstm_offset: int = 2


@dataclasses.dataclass(frozen=True)
class ParallelismPolicy:
    """Per-arch sharding policy (DESIGN.md §4)."""

    dp_only: bool = False         # tiny archs: replicate params, shard batch everywhere
    shard_vocab: bool = True      # embed/logits vocab dim over 'model'
    fsdp_params: bool = True      # shard param d_model dim over 'data' (ZeRO-3 style)
    remat: str = "full"           # "none" | "full" | "dots"
    scan_layers: bool = True      # lax.scan over the repeating layer block
    seq_shard_cache: bool = False  # KV cache: shard seq dim (when kv_heads < model axis)
    accum: int = 1                # gradient-accumulation microbatches (train)
    attn_chunk: int = 1024        # causal-attention query-chunk length
    pad_heads_to: int = 0         # pad q heads for TP divisibility (masked)
    pad_kv_heads_to: int = 0      # pad kv heads likewise
    pad_vocab_to: int = 0         # pad embed/lm_head rows (masked in CE)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    block_period: int = 1         # layer pattern repeats with this period
    pattern: tuple[BlockKind, ...] = ("attn",)   # one entry per layer-in-period
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    xlstm: XLSTMConfig | None = None
    frontend: str = "none"        # none | audio | vision
    n_frontend_tokens: int = 0    # vision: patch tokens prepended into the sequence
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    policy: ParallelismPolicy = dataclasses.field(default_factory=ParallelismPolicy)
    # which layers get MoE within the period (True entry per period position)
    moe_layers: tuple[bool, ...] = ()

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if len(self.pattern) != self.block_period:
            object.__setattr__(self, "pattern", tuple(["attn"] * self.block_period))
        if self.moe is not None and len(self.moe_layers) != self.block_period:
            object.__setattr__(self, "moe_layers", tuple([True] * self.block_period))
        if self.n_layers % self.block_period:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"block_period={self.block_period}"
            )

    @property
    def n_repeat(self) -> int:
        return self.n_layers // self.block_period

    # padded-for-parallelism sizes (pad rows are masked: zero gradient, zero
    # contribution — capacity is EXACTLY the assigned config's)
    @property
    def hq_eff(self) -> int:
        return max(self.n_heads, self.policy.pad_heads_to)

    @property
    def hkv_eff(self) -> int:
        return max(self.n_kv_heads, self.policy.pad_kv_heads_to)

    @property
    def vocab_eff(self) -> int:
        return max(self.vocab_size, self.policy.pad_vocab_to)

    def kind_of_layer(self, i: int) -> BlockKind:
        return self.pattern[i % self.block_period]

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        return bool(self.moe_layers[i % self.block_period])

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-FLOPs accounting)."""
        d, hd = self.d_model, self.head_dim
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size
        for i in range(self.block_period):
            kind = self.pattern[i]
            if kind == "attn":
                n += d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
            elif kind == "mamba":
                mc = self.mamba
                din = mc.expand * d
                dtr = mc.dt_rank or -(-d // 16)
                n += d * 2 * din + mc.d_conv * din + din * (dtr + 2 * mc.d_state)
                n += dtr * din + din * mc.d_state + din + din * d
            elif kind == "mlstm":
                xc = self.xlstm
                din = int(xc.proj_factor_mlstm * d)
                din -= din % xc.n_heads
                # up (d,2din) + q/k/v (din,din)x3 + wif (din,nh,2) + down
                n += 2 * d * din + 3 * din * din + 2 * din * xc.n_heads + din * d
            elif kind == "slstm":
                xc = self.xlstm
                din = int(xc.proj_factor_slstm * d)
                din -= din % xc.n_heads
                # up (d,din) + wx (din,4,din) + r (nh,hd,4,hd) + down
                n += d * din + 4 * din * din + 4 * din * (din // xc.n_heads) + din * d
            if self.is_moe_layer(i):
                mo = self.moe
                n += d * mo.n_total + 3 * mo.n_experts * d * mo.d_expert
                if mo.shared_d_ff:
                    n += 3 * d * mo.shared_d_ff
            elif kind == "attn" or kind == "mamba":
                if self.d_ff > 0 and kind == "attn":
                    n += 3 * d * self.d_ff
            # hybrid: mamba layers in jamba also carry the (MoE or dense) FFN
            if kind == "mamba" and not self.is_moe_layer(i) and self.d_ff > 0:
                n += 3 * d * self.d_ff
        # the period repeats n_repeat times; norms are negligible
        per_period = n - self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.vocab_size * d * (1 if self.tie_embeddings else 2) + per_period * self.n_repeat

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        mo = self.moe
        moe_layers_total = sum(
            1 for i in range(self.n_layers) if self.is_moe_layer(i)
        )
        inactive = 3 * self.d_model * mo.d_expert * (mo.n_experts - mo.top_k)
        return full - moe_layers_total * inactive
