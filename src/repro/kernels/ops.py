"""Public jit'd wrappers for the Pallas kernels.

`interpret` defaults to True because this container is CPU-only; on a real
TPU deployment set REPRO_PALLAS_INTERPRET=0 (or pass interpret=False) and the
same kernels compile to Mosaic.
"""

from __future__ import annotations

import os

import jax

from repro.kernels.brute_knn import brute_knn as _brute_knn
from repro.kernels.flash_attention import flash_attention as _flash_attention
from repro.kernels.candidate_topk import candidate_topk as _candidate_topk
from repro.kernels.csr_candidate_topk import (
    csr_candidate_topk as _csr_candidate_topk,
)
from repro.kernels.csr_candidate_topk_q8 import (
    csr_shortlist_q8 as _csr_shortlist_q8,
)
from repro.kernels.tile_count import tile_count as _tile_count
from repro.kernels.tile_count_multilevel import (
    tile_count_multilevel as _tile_count_multilevel,
)


def _default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def tile_count(level_arr, queries, radii, scale, tile, metric="l2", interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _tile_count(
        level_arr, queries, radii, scale, tile, metric=metric, interpret=interpret
    )


def tile_count_multilevel(
    tiles, queries, radii, levels, tile, nblks, metric="l2", interpret=None,
    active=None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return _tile_count_multilevel(
        tiles, queries, radii, levels, tile, nblks, metric=metric,
        interpret=interpret, active=active,
    )


def candidate_topk(candidates, valid, queries, k, metric="l2", d_chunk=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _candidate_topk(
        candidates, valid, queries, k, metric=metric, d_chunk=d_chunk, interpret=interpret
    )


def csr_candidate_topk(
    store, starts, ends, queries, k, n, row_cap, metric="l2", radii=None,
    center_cells=False, d_chunk=None, interpret=None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return _csr_candidate_topk(
        store, starts, ends, queries, k, n, row_cap, metric=metric,
        radii=radii, center_cells=center_cells, d_chunk=d_chunk,
        interpret=interpret,
    )


def csr_shortlist_q8(
    q_store, row_scales, starts, ends, queries, rerank_k, n, row_cap,
    metric="l2", d_chunk=None, interpret=None,
):
    interpret = _default_interpret() if interpret is None else interpret
    return _csr_shortlist_q8(
        q_store, row_scales, starts, ends, queries, rerank_k, n, row_cap,
        metric=metric, d_chunk=d_chunk, interpret=interpret,
    )


def brute_knn(queries, points, k, block_q=128, block_n=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _brute_knn(
        queries, points, k, block_q=block_q, block_n=block_n, interpret=interpret
    )


def flash_attention(q, k, v, causal=True, block_q=512, block_k=512, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret,
    )
