import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
report memory / cost / roofline terms.  No device allocation ever happens —
inputs are ShapeDtypeStructs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json

The two lines above this docstring MUST stay the first statements in the
file: jax locks the device count on first backend init.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCH_NAMES, get_config, long_context_mode  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import steps as st  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_chips  # noqa: E402
from repro.utils import scan as uscan  # noqa: E402


def probe_costs(cfg, shape: str, mesh, retrieval) -> dict:
    """Scan-corrected per-chip costs via affine depth extrapolation.

    cost_analysis() counts a while-loop body ONCE, so the production trace
    (layer scan + accum scan + chunk scans) under-reports.  We re-lower the
    model at depth 1 and 2 periods with every chunk loop UNROLLED (exact
    costs) and extrapolate affinely: total(R) = f1 + (R-1) * (f2 - f1).
    Exact when every repeated period costs the same, which holds by
    construction.  Known residual: the sLSTM time-scan body (<1% of FLOPs,
    EXPERIMENTS.md).  Probes are lower+compile only — no allocation."""
    chips = mesh_chips(mesh)
    roofs = []
    for k in (1, 2):
        pol = dataclasses.replace(
            cfg.policy, scan_layers=False, accum=1, attn_chunk=1 << 30
        )
        pcfg = dataclasses.replace(
            cfg, n_layers=k * cfg.block_period, policy=pol
        )
        with uscan.unroll_scans():
            lowered, _ = st.lower_cell(pcfg, shape, mesh, retrieval=retrieval)
        compiled = lowered.compile()
        roofs.append(rl.from_compiled(compiled, chips, hlo_text=compiled.as_text()))
    r1, r2 = roofs
    rep = cfg.n_repeat

    def affine(a, b):
        return a + (rep - 1) * (b - a)

    coll_kinds = {
        k: int(affine(r1.coll_by_kind.get(k, 0), r2.coll_by_kind.get(k, 0)))
        for k in set(r1.coll_by_kind) | set(r2.coll_by_kind)
    }
    corrected = rl.Roofline(
        flops=affine(r1.flops, r2.flops),
        hbm_bytes=affine(r1.hbm_bytes, r2.hbm_bytes),
        coll_bytes=affine(r1.coll_bytes, r2.coll_bytes),
        coll_by_kind=coll_kinds,
        chips=chips,
        fused_hbm_bytes=affine(r1.fused_hbm_bytes, r2.fused_hbm_bytes),
    ).finalize()
    return corrected.as_dict()


def cell_plan(arch: str, shape: str) -> str:
    """'run' | 'skip' | 'retrieval' for this (arch, shape) cell."""
    if shape != "long_500k":
        return "run"
    mode = long_context_mode(arch)
    if mode == "native":
        return "run"
    if mode == "retrieval":
        return "retrieval"   # beyond-paper: active-search retrieval memory
    return "skip"


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    step_cfg: st.StepConfig = st.StepConfig(),
    verbose: bool = True,
) -> dict:
    plan = cell_plan(arch, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "plan": plan}
    if plan == "skip":
        rec["status"] = "SKIP (pure full attention; DESIGN.md §5)"
        return rec

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    retrieval = (64, 512) if plan == "retrieval" else None

    t0 = time.time()
    lowered, kind = st.lower_cell(
        cfg, shape, mesh, step_cfg=step_cfg, retrieval=retrieval
    )
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    hlo = compiled.as_text()
    roof = rl.from_compiled(compiled, chips, hlo_text=hlo)
    mem = rl.memory_analysis_dict(compiled)
    mf = rl.model_flops(cfg, SHAPES[shape], kind)

    # scan-corrected costs (single-pod roofline table only; probes are 2 more
    # lower+compile passes at depth 1 and 2 periods)
    corrected = None
    if not multi_pod:
        t3 = time.time()
        corrected = probe_costs(cfg, shape, mesh, retrieval)
        rec["probe_s"] = round(time.time() - t3, 2)

    rec.update(
        status="OK",
        kind=kind,
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        roofline_raw=roof.as_dict(),
        roofline=corrected or roof.as_dict(),
        memory=mem,
        model_flops_total=mf,
        retrieval=plan == "retrieval",
    )
    use = rec["roofline"]
    rec["model_flops_ratio"] = (
        mf / (use["flops_per_chip"] * chips) if use["flops_per_chip"] else None
    )
    if verbose:
        ma = f"{(mem or {}).get('temp_size_in_bytes', 0)/2**30:.2f} GiB temp" if mem else "n/a"
        print(
            f"[{mesh_name}] {arch:18s} {shape:12s} {kind:7s} OK  "
            f"compile {t2-t1:6.1f}s  "
            f"C/M/X = {use['compute_s']*1e3:.1f}/{use['memory_s']*1e3:.1f}/"
            f"{use['collective_s']*1e3:.1f} ms  "
            f"bottleneck={use['bottleneck']}  "
            f"6ND/HLO={rec['model_flops_ratio'] if rec['model_flops_ratio'] is None else round(rec['model_flops_ratio'], 3)}  "
            f"mem: {ma}",
            flush=True,
        )
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true", help="every (arch x shape) cell")
    ap.add_argument("--out", default=None, help="append JSON records here")
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    if args.all:
        cells = [(a, s) for a in ARCH_NAMES for s in SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    step_cfg = st.StepConfig(accum=args.accum)

    records, failures = [], 0
    for arch, shape in cells:
        for multi_pod in meshes:
            try:
                rec = run_cell(arch, shape, multi_pod, step_cfg)
            except Exception as e:  # a failing cell is a bug in the system
                failures += 1
                rec = {
                    "arch": arch, "shape": shape,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "status": f"FAIL: {type(e).__name__}: {e}",
                }
                print(f"FAIL {arch} {shape} multi_pod={multi_pod}", flush=True)
                traceback.print_exc()
            records.append(rec)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")

    ok = sum(1 for r in records if r.get("status") == "OK")
    skip = sum(1 for r in records if str(r.get("status", "")).startswith("SKIP"))
    print(f"\ndry-run: {ok} OK, {skip} SKIP, {failures} FAIL / {len(records)} cells")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
