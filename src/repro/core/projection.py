"""Projection front-end: original d-dim space -> low-dim grid space.

The paper works directly on 2-D data ("this approach can be applied to higher
dimensional data, though it will require a much bigger memory").  A dense
d-dim raster is memory-exponential, so production use puts a projection in
front of the grid and re-ranks candidates in the original space (DESIGN.md §2).

Projections are pytrees; all functions are jit/vmap friendly.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Projection(NamedTuple):
    """Affine map  x -> x @ matrix  with grid extents [lo, hi] per grid dim."""

    matrix: jax.Array  # (d, gd) float32
    lo: jax.Array      # (gd,) float32
    hi: jax.Array      # (gd,) float32

    @property
    def grid_dim(self) -> int:
        return self.matrix.shape[1]


def apply(proj: Projection, x: jax.Array) -> jax.Array:
    """Project points (..., d) into grid space (..., gd)."""
    return x.astype(jnp.float32) @ proj.matrix


def _extents(g: jax.Array, margin: float) -> tuple[jax.Array, jax.Array]:
    lo = jnp.min(g, axis=0)
    hi = jnp.max(g, axis=0)
    span = jnp.maximum(hi - lo, 1e-6)
    return lo - margin * span, hi + margin * span


def identity_projection(points: jax.Array, margin: float = 0.01) -> Projection:
    """Paper-faithful: grid space IS the data space (d == gd)."""
    d = points.shape[-1]
    mat = jnp.eye(d, dtype=jnp.float32)
    lo, hi = _extents(points.astype(jnp.float32), margin)
    return Projection(mat, lo, hi)


def gaussian_projection(
    key: jax.Array, points: jax.Array, grid_dim: int = 2, margin: float = 0.01
) -> Projection:
    """Random Gaussian projection (Johnson-Lindenstrauss style) to `grid_dim`."""
    d = points.shape[-1]
    mat = jax.random.normal(key, (d, grid_dim), dtype=jnp.float32) / jnp.sqrt(d)
    g = points.astype(jnp.float32) @ mat
    lo, hi = _extents(g, margin)
    return Projection(mat, lo, hi)


def pca_projection(points: jax.Array, grid_dim: int = 2, margin: float = 0.01) -> Projection:
    """Top-`grid_dim` principal directions — a better-behaved learned projection.

    Computed with one eigendecomposition of the (d, d) covariance; d is the
    embedding dim (<= a few thousand), never N.
    """
    x = points.astype(jnp.float32)
    mu = jnp.mean(x, axis=0, keepdims=True)
    xc = x - mu
    cov = (xc.T @ xc) / x.shape[0]
    _, vecs = jnp.linalg.eigh(cov)          # ascending eigenvalues
    mat = vecs[:, -grid_dim:][:, ::-1]       # (d, gd), top components first
    g = x @ mat
    lo, hi = _extents(g, margin)
    return Projection(mat, lo, hi)


def to_grid_coords(proj: Projection, x: jax.Array, grid_size: int) -> jax.Array:
    """Continuous grid coordinates in [0, grid_size) per grid dim, float32.

    Pixel (i, j) covers [i, i+1) x [j, j+1); a point's pixel is floor(coords).
    """
    g = apply(proj, x)
    span = jnp.maximum(proj.hi - proj.lo, 1e-6)
    c = (g - proj.lo) / span * grid_size
    return jnp.clip(c, 0.0, grid_size - 1e-3)


def to_cells(proj: Projection, x: jax.Array, grid_size: int) -> jax.Array:
    """Integer cell indices (..., gd) int32 in [0, grid_size)."""
    return jnp.floor(to_grid_coords(proj, x, grid_size)).astype(jnp.int32)
