"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoint/restart -> fault tolerance.  Runs a real (reduced-config) model on
whatever devices exist; the same loop drives the production mesh on TPU.

Usage (CPU, ~100M model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke \
      --steps 300 --ckpt-dir /tmp/ckpt --d-model 512

Fault-tolerance drills (exercised in tests):
  * SIGTERM mid-run -> checkpoint + clean exit; rerun resumes at that step.
  * --fail-at k injects a fault at step k; the supervisor restarts from the
    last checkpoint (node-failure recovery).
  * --elastic-to d,m restores the checkpoint onto a DIFFERENT mesh.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.data.pipeline import DataConfig, Prefetcher
from repro.checkpoint.store import CheckpointManager
from repro.launch import ft
from repro.launch import steps as st
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.parallel import sharding as sh


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 256
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    compress_grads: bool = False
    accum: int = 1
    fail_at: int = -1          # inject a fault at this step (tests)
    lr: float = 3e-4


def train_loop(
    cfg,                      # ModelConfig
    tc: TrainConfig,
    mesh,
    log=print,
) -> dict:
    """One supervised run; resumes from the newest checkpoint if present."""
    opt_cfg = adamw.AdamWConfig(lr=tc.lr, total_steps=tc.steps, warmup_steps=max(tc.steps // 20, 1))
    step_cfg = st.StepConfig(accum=tc.accum, compress_grads=tc.compress_grads)
    _, state_abs, state_sh, jit_for = st.make_train_step(cfg, opt_cfg, mesh, step_cfg)

    mgr = CheckpointManager(tc.ckpt_dir) if tc.ckpt_dir else None
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        start = mgr.latest_step()
        state = mgr.restore(start, state_abs, shardings=state_sh)
        log(f"[train] resumed from checkpoint step {start}")
    else:
        state = st.init_train_state(
            jax.random.PRNGKey(tc.seed), cfg, opt_cfg, step_cfg, mesh
        )

    dc = DataConfig(
        global_batch=tc.batch, seq_len=tc.seq, vocab_size=cfg.vocab_size, seed=tc.seed
    )
    pf = Prefetcher(dc, model_cfg=cfg, start_step=start)
    timer = ft.StepTimer()
    step_fn = None
    losses: list[float] = []

    try:
        with ft.PreemptionGuard() as guard:
            for step, host_batch in pf:
                if step >= tc.steps:
                    break
                if step == tc.fail_at:
                    raise RuntimeError(f"injected fault at step {step}")
                batch = jax.tree.map(jax.numpy.asarray, host_batch)
                if step_fn is None:
                    batch_abs = jax.tree.map(
                        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch
                    )
                    with mesh:
                        step_fn = jit_for(batch_abs)
                t0 = time.time()
                with mesh:
                    state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                stats = timer.record(step, time.time() - t0)
                losses.append(loss)
                if step % tc.log_every == 0:
                    log(
                        f"[train] step {step:5d} loss {loss:8.4f} "
                        f"gnorm {float(metrics['grad_norm']):7.3f} "
                        f"lr {float(metrics['lr']):.2e} "
                        f"{stats.seconds*1e3:7.1f} ms"
                        + ("  STRAGGLER" if stats.is_straggler else "")
                    )
                next_step = step + 1
                if mgr is not None and (
                    next_step % tc.ckpt_every == 0 or guard.draining
                ):
                    mgr.save(next_step, state)
                if guard.draining:
                    log(f"[train] preempted: drained at step {next_step}")
                    break
    finally:
        pf.close()
        if mgr is not None:
            mgr.wait()

    final_step = int(np.asarray(jax.device_get(state["step"])))
    return {"state": state, "losses": losses, "final_step": final_step,
            "stragglers": timer.straggler_steps}


def run(cfg, tc: TrainConfig, mesh, max_restarts: int = 3, log=print) -> dict:
    """Supervised training with restart-from-checkpoint on failure."""
    out: dict = {}

    def attempt():
        nonlocal out
        out = train_loop(cfg, tc, mesh, log=log)
        return out["final_step"]

    ft.run_with_restarts(
        attempt,
        max_restarts=max_restarts,
        on_restart=lambda k, e: (
            log(f"[train] restart {k} after: {type(e).__name__}: {e}"),
            # the injected fault only fires once
            setattr(tc, "fail_at", -1),
        ),
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES, default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="mesh data-axis size")
    ap.add_argument("--model", type=int, default=1, help="mesh model-axis size")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override smoke d_model (scale to ~100M params)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    if args.d_model:
        cfg = dataclasses.replace(
            cfg,
            d_model=args.d_model,
            head_dim=args.d_model // cfg.n_heads,
            d_ff=(4 * args.d_model if cfg.d_ff else 0),
        )
    if args.layers:
        per = cfg.block_period
        cfg = dataclasses.replace(cfg, n_layers=max(per, args.layers // per * per))

    mesh = make_host_mesh(args.data, args.model)
    tc = TrainConfig(
        steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        compress_grads=args.compress, accum=args.accum, fail_at=args.fail_at,
    )
    out = run(cfg, tc, mesh)
    print(
        f"[train] done: {out['final_step']} steps, "
        f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}"
    )


if __name__ == "__main__":
    main()
