"""Beyond-paper: active-search retrieval memory makes long-context decode
sub-quadratic for ATTENTION models (the long_500k path for full-attention
archs — DESIGN.md §5).

  PYTHONPATH=src python examples/long_context_retrieval.py

Per decode step the token attends to (local window) U (top-m positions
retrieved by active search over a grid index of key summaries) instead of the
full KV cache.  The demo checks retrieval fidelity: positions whose keys
resemble the query are found, and the retrieved-attention output stays close
to full attention while touching O(w + m) << T entries.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import retrieval_memory as rmem
from repro.models import model as M

cfg = get_smoke("internlm2-1.8b")
params = M.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

B, S = 1, 512                 # demo scale; the dry-run proves 524,288
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
print(f"[example] prefill {S} tokens ...")
_, caches, _ = M.prefill(params, cfg, {"tokens": tokens}, cache_len=S + 8)

# ---- build the retrieval index from layer-0 key summaries ------------------
mem_cfg = rmem.RetrievalMemoryConfig(
    n_retrieved=32, local_window=64,
    grid=rmem.RetrievalMemoryConfig().grid,
)
proj = rmem.make_projection(jax.random.PRNGKey(1), cfg.head_dim)
k_cache = caches[0]["k"][0]                      # (B, T, Hkv, hd) layer 0
keys = rmem.key_summary(k_cache[0, :S])          # (S, hd)
index = rmem.build_memory_index(keys, mem_cfg, proj)
print(f"[example] retrieval index over {index.n_points} positions")

# ---- decode one token both ways ---------------------------------------------
tok = jnp.asarray([5], jnp.int32)
pos = jnp.int32(S)

t0 = time.perf_counter()
full_logits, _, _ = M.decode_step(params, cfg, caches, tok, pos)
jax.block_until_ready(full_logits)
t_full = time.perf_counter() - t0

q_sum = rmem.query_summary(keys[S - 1][None, None, :])   # stand-in query
retrieved, ok = rmem.retrieve_positions(index, mem_cfg, q_sum)
print(f"[example] retrieved positions[:8]: {np.asarray(retrieved[0][:8])}")

t0 = time.perf_counter()
r_logits, _, _ = M.decode_step(
    params, cfg, caches, tok, pos,
    retrieved=(retrieved, ok, mem_cfg.local_window),
)
jax.block_until_ready(r_logits)
t_ret = time.perf_counter() - t0

# anchor: when (local window) U (retrieved) covers EVERY position, the
# retrieval path must reproduce full attention exactly
all_pos = jnp.arange(S - 64, dtype=jnp.int32)[None, :]
anchor_logits, _, _ = M.decode_step(
    params, cfg, caches, tok, pos,
    retrieved=(all_pos, jnp.ones_like(all_pos, bool), 72),
)

def cos(a, b):
    a = np.asarray(a.astype(jnp.float32)).ravel()
    b = np.asarray(b.astype(jnp.float32)).ravel()
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))

print(f"[example] full-coverage anchor: cos(logits) = "
      f"{cos(anchor_logits, full_logits):.4f}  (must be ~1.0)")
print(f"[example] sparse {mem_cfg.local_window}+{mem_cfg.n_retrieved} of {S}: "
      f"cos(logits) = {cos(r_logits, full_logits):.4f}  (untrained weights -> "
      "diffuse attention; trained models concentrate on retrieved hits)")
print(f"[example] decode: full {t_full*1e3:.1f} ms, retrieved {t_ret*1e3:.1f} ms "
      "(CPU timings are indicative only; the asymptotic win is O(w+m) vs O(T))")
