"""Logical activation-axis rules -> with_sharding_constraint (MaxText-style).

Model code annotates activations with LOGICAL axis names ("batch", "seq",
"heads", "vocab", "experts", ...).  The launch layer installs a mapping from
logical names to mesh axes for the duration of a trace; outside any mapping
(unit tests, single-device smoke runs) constrain() is a no-op.

Why this exists: with FSDP-sharded weights and no activation constraints,
GSPMD's cheapest-local-op strategy is to REPLICATE the batch dim and
partial-sum over the fsdp axis — measured 221 GiB/device temp on the
minitron-8b train cell.  Pinning the batch axis at layer boundaries flips the
partitioner to the intended all-gather-weights (ZeRO-3) schedule.
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX: contextvars.ContextVar = contextvars.ContextVar("axis_rules", default=None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: dict[str, Any]):
    """rules: logical name -> mesh axis | tuple of axes | None."""
    tok = _CTX.set((mesh, dict(rules)))
    try:
        yield
    finally:
        _CTX.reset(tok)


def current_rules():
    return _CTX.get()


def _resolve(entry: Any, rules: dict) -> tuple:
    """logical entry -> flat tuple of mesh axis names."""
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        out: list = []
        for e in entry:
            out.extend(_resolve(e, rules))
        return tuple(out)
    mapped = rules.get(entry, None)
    if mapped is None:
        return ()
    if isinstance(mapped, (tuple, list)):
        return tuple(a for a in mapped if a is not None)
    return (mapped,)


def spec_for(shape: tuple, logical: tuple, mesh: Mesh, rules: dict) -> P:
    """Divisibility-checked PartitionSpec for `shape` from logical names."""
    entries = []
    used: set = set()
    for size, name in zip(shape, logical):
        axes = []
        prod = 1
        for a in _resolve(name, rules):
            if a in used or a not in mesh.axis_names:
                continue
            asz = mesh.shape[a]
            if size % (prod * asz) == 0:
                axes.append(a)
                prod *= asz
                used.add(a)
        entries.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return P(*entries)


def constrain(x: jax.Array, *logical) -> jax.Array:
    """Pin `x` to the sharding its logical axes imply.  No-op outside rules."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical) != x.ndim:
        raise ValueError(f"constrain: {len(logical)} names for rank-{x.ndim} array")
    spec = spec_for(tuple(x.shape), tuple(logical), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def default_rules(cfg, mesh: Mesh, batch_size: int) -> dict[str, Any]:
    """Standard logical->mesh mapping for one step trace."""
    from repro.parallel import sharding as sh

    dp = sh.dp_axes_for(batch_size, mesh, cfg.policy.dp_only)
    mdl = None if cfg.policy.dp_only else (
        "model" if "model" in mesh.axis_names else None
    )
    # decode attention must match the KV-cache layout (sharding.cache_pspec):
    # kv-heads-sharded cache -> per-head-local decode; hd-sharded cache ->
    # shard decode q/k on head_dim so the score contraction partial-sums into
    # one small (B,H,T) all-reduce instead of all-gathering the cache
    # (measured: 2.2 GB/step of f32 cache gathers on internlm2 decode_32k).
    kv_divides = mdl is None or cfg.hkv_eff % mesh.shape[mdl] == 0
    return {
        "dec_heads": (mdl if kv_divides else None),
        "dec_hd": (None if kv_divides else mdl),
        "batch": dp,
        "seq": None,            # sequence/context parallelism: set to an axis
        "heads": mdl,
        "kv_heads": mdl,
        # NEVER map head_dim to a mesh axis: it is the attention contraction
        # dim, and sharding it costs an all-reduce per score matmul
        # (EXPERIMENTS.md §Perf iteration 1).  spec_for drops non-divisible
        # head counts to replicated instead.
        "head_dim": None,
        "ff": mdl,
        "vocab": mdl,
        "experts": mdl,
        "embed": None,
        "inner": mdl,           # mamba/xlstm d_inner
        "cache_seq": None,
    }
