"""musicgen-medium [audio] — decoder-only over EnCodec tokens
(arXiv:2306.05284; hf).  48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.

The EnCodec frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d).  long_500k: SKIP (full attention)."""

from repro.models.config import ModelConfig, ParallelismPolicy

LONG_CONTEXT = "skip"

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    frontend="audio",
    # 24 MHA heads don't divide model=16: pad to 32 (masked pad heads) —
    # without this, replicated attention costs 16x redundant compute and the
    # head_dim fallback cost 78 s of all-reduce (EXPERIMENTS.md §Perf it. 1)
    policy=ParallelismPolicy(remat="full", scan_layers=True, accum=4,
                             pad_heads_to=32, pad_kv_heads_to=32),
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke",
    family="audio",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=8,
    d_ff=256,
    vocab_size=256,
    frontend="audio",
)
