"""End-to-end facade throughput: queries/sec for EVERY registered backend
through the one `ActiveSearcher` handle, plus the facade-overhead delta
(handle call vs invoking the registered BackendImpl directly).

The overhead delta is the price of the facade itself — plan validation,
device placement, the chunking wrapper — measured against the exact same
underlying impl, so it should sit in the noise floor.  Each backend also
records its candidate-stage PEAK intermediate bytes: the gather-based paths
(jnp, pallas_gather) materialize the full (B, w*row_cap) four-field window
in HBM before ranking, while the fused pallas default only writes the
(B, k) result pair.  Results land in BENCH_e2e.json (next to
BENCH_kernels.json; see REPRO_BENCH_ARTIFACTS) so CI records per-backend
throughput on every push.

Env knobs:
  REPRO_BENCH_QUICK=1      shrink to CI-friendly sizes
  REPRO_BENCH_ARTIFACTS=D  directory for BENCH_e2e.json (default ".")
"""

from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, timeit
from repro import api


def _quick() -> bool:
    return os.environ.get("REPRO_BENCH_QUICK", "0") == "1"


def main() -> None:
    rng = np.random.default_rng(0)
    n, b, k = (5_000, 32, 11) if _quick() else (100_000, 256, 11)
    cfg = api.GridConfig(grid_size=256, tile=16, n_classes=3, window=32,
                         row_cap=32, r0=10, k_slack=2.0)
    pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 3, size=n), jnp.int32)
    searcher = api.ActiveSearcher.build(
        pts, labels=labels, cfg=cfg, proj=api.identity_projection(pts)
    )
    q = jnp.asarray(rng.normal(size=(b, 2)), jnp.float32)

    csv = Csv("backend,queries_per_s,facade_us_per_q,facade_overhead_us_per_q,"
              "cand_stage_bytes,parity_vs_jnp")
    results: dict = {"schema": 2, "timestamp": time.time(), "quick": _quick(),
                     "n": n, "batch": b, "k": k, "backends": {}}
    # the jnp reference FIRST (registered_backends() is sorted, so relying on
    # iteration order would leave earlier backends without a reference); the
    # exact comparator ranks the whole datastore, so only grid-backed
    # backends are expected to agree bit-for-bit — others record parity None
    ref_ids = np.asarray(searcher.search(q, k).ids)
    grid_backed = ("jnp", "pallas", "pallas_gather")
    repeats = 3 if _quick() else 5

    # candidate-stage PEAK intermediate per full batch: the gather-based
    # paths materialize (B, w*row_cap) of points(f32 d) + coords(f32 2) +
    # labels(i32) + ids(i32) + valid(bool) before ranking; the fused
    # csr_candidate_topk path only ever writes the (B, k) result pair
    d = int(pts.shape[1])
    cand = cfg.window * cfg.row_cap
    gather_bytes = b * cand * (4 * d + 8 + 4 + 4 + 1)
    fused_bytes = b * k * (4 + 4)
    cand_bytes = {"jnp": gather_bytes, "pallas_gather": gather_bytes,
                  "pallas": fused_bytes}
    results["candidate_intermediate"] = {
        "gather_bytes": gather_bytes,
        "fused_bytes": fused_bytes,
        "reduction_x": gather_bytes / fused_bytes,
    }
    for name in api.registered_backends():
        impl = api.get_backend(name)
        if impl.search is None:
            csv.row(name, "-", "-", "-", "-", "count-only")
            continue
        if name == "sharded":
            # needs a mesh-built handle (ActiveSearcher.build_sharded);
            # the single-host CI bench skips it rather than faking a mesh
            csv.row(name, "-", "-", "-", "-", "skipped (needs mesh)")
            continue
        planned = searcher.with_plan(backend=name)
        t_facade = timeit(lambda: planned.search(q, k).ids,
                          repeats=repeats, warmup=1)
        t_direct = timeit(lambda: impl.search(planned, q, k, "refined").ids,
                          repeats=repeats, warmup=1)
        res = planned.search(q, k)
        parity = (
            bool(np.array_equal(np.asarray(res.ids), ref_ids))
            if name in grid_backed else None
        )
        overhead = t_facade - t_direct
        results["backends"][name] = {
            "queries_per_s": b / t_facade,
            "facade_s": t_facade,
            "direct_s": t_direct,
            "facade_overhead_s": overhead,
            "candidate_stage_bytes": cand_bytes.get(name),
            "parity_vs_jnp": parity,
        }
        cb = cand_bytes.get(name)
        csv.row(name, f"{b / t_facade:.1f}", f"{t_facade * 1e6 / b:.1f}",
                f"{overhead * 1e6 / b:+.1f}",
                "-" if cb is None else f"{cb:,}",
                "n/a" if parity is None else parity)

    art_dir = os.environ.get("REPRO_BENCH_ARTIFACTS", ".")
    path = os.path.join(art_dir, "BENCH_e2e.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_e2e] wrote {path}", flush=True)
    return csv


if __name__ == "__main__":
    main()
