"""Circle counts from the mip pyramid — the paper's "zoom" made shape-static.

The paper counts points inside a circle of radius r by scanning all pixels in
the circle (cost O(r^2), unbounded).  TPU adaptation (DESIGN.md §2): pick the
pyramid level l where the circle's diameter fits a fixed T x T tile
(2r + 1 <= T * 2**l), gather ONE (T, T, C) tile around the query, apply the
circular mask against cell centers, and sum.  Cost is O(T^2 * C) regardless of
r and N — level selection IS the zoom.

Level 0 reproduces the paper exactly (pixel centers within r); coarser levels
approximate the circle with 2**l-pixel cells, which only matters transiently
inside the radius loop (the final count/classify can be re-done at level 0
when the radius permits).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.grid import GridConfig, GridIndex


def level_for_radius(r: jax.Array, cfg: GridConfig) -> jax.Array:
    """Smallest level whose T-cell window FULLY contains the circle.

    Worst case (query at a cell edge) the window covers (T/2 - 1.5) level
    cells of radius, so we need 2**l >= 2r / (T - 3).  Guarantees the masked
    window count equals the full circle count (tests + kernel contract).
    GridConfig rejects tile <= 3, so the (T - 3) margin is always positive
    here."""
    need = 2.0 * r.astype(jnp.float32) / jnp.float32(cfg.tile - 3)
    l = jnp.ceil(jnp.log2(jnp.maximum(need, 1.0))).astype(jnp.int32)
    return jnp.clip(l, 0, cfg.levels - 1)


def _count_at_level(
    arr: jax.Array, level: int, q: jax.Array, r: jax.Array, cfg: GridConfig
) -> jax.Array:
    """Masked circle count from one pyramid level.  arr: (S, S, C) int32."""
    t = cfg.tile
    s = arr.shape[0]
    scale = 1 << level
    qx, qy = q[0], q[1]
    cx = jnp.floor(qx / scale).astype(jnp.int32)
    cy = jnp.floor(qy / scale).astype(jnp.int32)
    ox = jnp.clip(cx - t // 2, 0, s - t)
    oy = jnp.clip(cy - t // 2, 0, s - t)
    tile = lax.dynamic_slice(arr, (ox, oy, 0), (t, t, arr.shape[-1]))

    # cell centers in base-pixel units
    ci = (ox + jnp.arange(t, dtype=jnp.float32) + 0.5) * scale
    cj = (oy + jnp.arange(t, dtype=jnp.float32) + 0.5) * scale
    rf = r.astype(jnp.float32)
    if cfg.metric == "l1":
        dist = jnp.abs(ci - qx)[:, None] + jnp.abs(cj - qy)[None, :]
        mask = dist <= rf
    else:
        d2 = (ci - qx)[:, None] ** 2 + (cj - qy)[None, :] ** 2
        mask = d2 <= rf * rf
    return jnp.sum(tile * mask[:, :, None].astype(jnp.int32), axis=(0, 1))


def count_in_circle(
    index: GridIndex, cfg: GridConfig, q: jax.Array, r: jax.Array
) -> jax.Array:
    """Per-class counts (C,) of points whose pixel center lies within radius r
    of the continuous grid position q (2,).

    counter="pyramid": one fixed-size tile gather at level l(r) (L2/L1 mask).
    counter="sat": EXACT L-inf (square) count — four gathers, any radius
    (integral.py; beyond-paper variant)."""
    if cfg.counter == "sat":
        from repro.core import integral as integral_lib
        return integral_lib.count_linf(index.sat, q, r)
    level = level_for_radius(r, cfg)
    branches = [
        lambda _, a=arr, lv=lv: _count_at_level(a, lv, q, r, cfg)
        for lv, arr in enumerate(index.pyramid)
    ]
    return lax.switch(level, branches, None)


def count_total(index: GridIndex, cfg: GridConfig, q: jax.Array, r: jax.Array) -> jax.Array:
    return count_in_circle(index, cfg, q, r).sum()


def seed_radius(
    index: GridIndex, cfg: GridConfig, q: jax.Array, k: int
) -> jax.Array:
    """Per-query Eq.-1 start radius from the pyramid's top levels.

    The coarse pyramid levels are a free local-density sketch: probe the
    circle count at the largest window-contained radius of the top level
    (and of the level below it, whose finer probe wins whenever it already
    sees >= k points), then apply ONE Eq.-1 step to land the start radius
    near the query's own k-neighborhood scale.  Queries whose probes see no
    mass fall back to the global cfg.r0.

    This only changes WHERE the radius loop starts — never what it returns:
    the loop's acceptance band and fallback logic are untouched, so results
    follow whatever radius the schedule converges to.  Shared verbatim by
    the per-query jnp path and (under vmap) the batched pallas path, so the
    seeds are bit-identical across backends by construction.
    """
    r_max = jnp.int32(cfg.max_radius)
    top = cfg.levels - 1
    kf = jnp.float32(k)

    def eq1_step(r_probe, n_probe):
        ratio = jnp.sqrt(kf / jnp.maximum(n_probe, 1).astype(jnp.float32))
        return jnp.round(r_probe.astype(jnp.float32) * ratio).astype(jnp.int32)

    # largest radius whose circle is FULLY contained by the T-cell window at
    # level l (the level_for_radius margin, inverted): r = (T - 3) * 2**l / 2
    r1 = jnp.int32(((cfg.tile - 3) << top) // 2)
    n1 = _count_at_level(index.pyramid[top], top, q, r1, cfg).sum()
    est = eq1_step(r1, n1)
    if top >= 1:
        r2 = jnp.int32(((cfg.tile - 3) << (top - 1)) // 2)
        n2 = _count_at_level(index.pyramid[top - 1], top - 1, q, r2, cfg).sum()
        est = jnp.where(n2 >= k, eq1_step(r2, n2), est)
    return jnp.where(n1 > 0, jnp.clip(est, 1, r_max), jnp.int32(cfg.r0))


def radius_search(
    index: GridIndex, cfg: GridConfig, q: jax.Array, k: int,
    adaptive_r0: bool = False,
) -> dict[str, jax.Array]:
    """The paper's Eq. 1:  r_{t+1} = round(r_t * sqrt(k / n_t)).

    Faithful except for two production guards (DESIGN.md §8): an iteration cap
    (Eq. 1 oscillates on quantized counts) and an acceptance band
    n in [k, ceil(k_slack * k)] (k_slack=1.0 is the paper's exact n == k stop).
    Tracks the smallest radius seen with n >= k as the fallback answer.

    adaptive_r0=True seeds the start radius per query from the pyramid's
    top levels (`seed_radius`) instead of the global cfg.r0.
    """
    k_hi = jnp.int32(max(k, math.ceil(k * cfg.k_slack)))
    r_max = jnp.int32(cfg.max_radius)
    sentinel = r_max + 1

    def cond(state):
        t, _r, done, _best = state
        return jnp.logical_and(t < cfg.max_iters, jnp.logical_not(done))

    def body(state):
        t, r, _done, best = state
        n = count_total(index, cfg, q, r)
        hit = jnp.logical_and(n >= k, n <= k_hi)
        best = jnp.where(n >= k, jnp.minimum(best, r), best)
        # Eq. 1 with integer rounding
        ratio = jnp.sqrt(k / jnp.maximum(n, 1).astype(jnp.float32))
        r_new = jnp.round(r.astype(jnp.float32) * ratio).astype(jnp.int32)
        r_new = jnp.where(n == 0, r * 2, r_new)
        r_new = jnp.clip(r_new, 1, r_max)
        # force progress when rounding stalls
        r_new = jnp.where(
            jnp.logical_and(r_new == r, jnp.logical_not(hit)),
            r + jnp.where(n < k, 1, -1),
            r_new,
        )
        r_next = jnp.where(hit, r, jnp.clip(r_new, 1, r_max))
        return t + 1, r_next, hit, best

    # GridConfig rejects out-of-range r0 eagerly, so no silent clip here
    r0 = seed_radius(index, cfg, q, k) if adaptive_r0 else jnp.int32(cfg.r0)
    t, r, converged, best = lax.while_loop(cond, body, (jnp.int32(0), r0, False, sentinel))

    r_final = jnp.where(converged, r, jnp.where(best <= r_max, best, r_max))
    n_final = count_total(index, cfg, q, r_final)
    return {
        "radius": r_final,
        "count": n_final,
        "iters": t,
        "converged": converged,
    }
