"""Eq. 1 radius-iteration behaviour: convergence rate, iteration counts, and
the effect of r0 (the paper observes r0=100 'seems too small' for sparse
data — time grows as the radius walks out)."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Csv, paper_data
from repro.core import pyramid as pyr
from repro.core import projection as proj_lib
from repro.core.grid import GridConfig, build_index
from repro.core.projection import identity_projection

K = 11


def main(n=20_000, r0s=(2, 8, 32, 100, 400)) -> None:
    rng = np.random.default_rng(0)
    pts, labels = paper_data(rng, n)
    q, _ = paper_data(rng, 200)
    csv = Csv("r0,converged_frac,mean_iters,mean_radius,mean_count")
    for r0 in r0s:
        cfg = GridConfig(grid_size=1024, tile=16, n_classes=3, window=64,
                         row_cap=64, r0=r0, k_slack=2.0)
        idx = build_index(pts, cfg, identity_projection(pts), labels=labels)

        def stats_of(one_q):
            qg = proj_lib.to_grid_coords(idx.proj, one_q, cfg.grid_size)
            return pyr.radius_search(idx, cfg, qg, K)

        stats = jax.vmap(stats_of)(q)
        csv.row(
            r0,
            f"{float(jnp.mean(stats['converged'].astype(jnp.float32))):.3f}",
            f"{float(jnp.mean(stats['iters'].astype(jnp.float32))):.2f}",
            f"{float(jnp.mean(stats['radius'].astype(jnp.float32))):.1f}",
            f"{float(jnp.mean(stats['count'].astype(jnp.float32))):.1f}",
        )
    return csv


if __name__ == "__main__":
    main()
