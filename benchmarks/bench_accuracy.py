"""Accuracy benchmark -> BENCH_accuracy.json (paper §3 + quantized recall).

Two sections:

  paper      The §3 experiment at faithful settings (3000x3000 image,
             r0=100, k=11, 3 classes, exact kNN as ground truth; the paper
             reports 'up to 98%').
  quantized  The recall contract of the `pallas_q8` backend: recall@k vs
             the exact comparator for every grid-backed backend, the
             fraction of queries whose int8 shortlist contains ALL of the
             exact fused top-k (the conditional-bit-parity precondition),
             and the candidate-stage bytes moved per batch q8 vs fp32.
             Runs at d=32 with planted 2-d structure (strong first two
             dims) so the PCA grid projection preserves neighborhoods —
             the regime the int8 store targets: real feature dims, not the
             paper's d=2 toy where a 4-byte/row scale could never win 3x.

The JSON records the floors (`recall_floor`, `bytes_reduction_floor`)
alongside the measurements; `scripts/render_bench_table.py --check` fails
loudly when `pallas_q8` recall@k drops below the floor, the bytes
reduction regresses, or any exact backend's parity flag flips — same
pattern as the existing parity gates.

Env knobs:
  REPRO_BENCH_QUICK=1      shrink to CI-friendly sizes
  REPRO_BENCH_ARTIFACTS=D  directory for BENCH_accuracy.json (default ".")
"""

from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, paper_data
from repro import api
from repro.api import ActiveSearcher, identity_projection
from repro.configs.paper_active_search import K, N_CLASSES, N_QUERIES, PAPER_GRID
from repro.core import batched

RECALL_FLOOR = 0.95
BYTES_REDUCTION_FLOOR = 3.0


def _quick() -> bool:
    return os.environ.get("REPRO_BENCH_QUICK", "0") == "1"


def _paper_section(csv: Csv) -> dict:
    ns = (1_000,) if _quick() else (1_000, 10_000, 100_000)
    seeds = (0,) if _quick() else (0, 1, 2)
    rows = []
    for n in ns:
        for seed in seeds:
            rng = np.random.default_rng(seed)
            pts, labels = paper_data(rng, n, N_CLASSES)
            searcher = ActiveSearcher.build(
                pts, labels=labels, cfg=PAPER_GRID,
                proj=identity_projection(pts),
            )
            q, _ = paper_data(rng, N_QUERIES)
            truth = searcher.with_plan(backend="exact").classify(q, K)
            for mode in ("paper", "refined"):
                pred = searcher.classify(q, K, mode=mode)
                acc = float(np.mean(np.asarray(pred) == np.asarray(truth)))
                csv.row("paper", n, seed, mode, f"{acc:.3f}")
                rows.append({"n": n, "seed": seed, "mode": mode,
                             "accuracy_vs_exact": acc})
    return {"k": K, "rows": rows}


def _planted(rng, m: int, d: int) -> jnp.ndarray:
    """d-dim points whose neighborhoods live in the first two dims."""
    x = np.zeros((m, d), np.float32)
    x[:, :2] = rng.normal(size=(m, 2)) * 50.0
    x[:, 2:] = rng.normal(size=(m, d - 2)) * 0.3
    return jnp.asarray(x)


def _quantized_section(csv: Csv) -> dict:
    rng = np.random.default_rng(0)
    n, b = (5_000, 64) if _quick() else (20_000, 128)
    k, d = 10, 32
    cfg = api.GridConfig(grid_size=256, tile=16, n_classes=3, window=32,
                         row_cap=32, r0=10, k_slack=2.0)
    pts = _planted(rng, n, d)
    labels = jnp.asarray(rng.integers(0, 3, size=n), jnp.int32)
    searcher = ActiveSearcher.build(pts, labels=labels, cfg=cfg)
    q = _planted(rng, b, d)

    truth = searcher.with_plan(backend="exact").search(q, k)
    t_valid = float(jnp.sum(truth.valid))
    fused = searcher.with_plan(backend="pallas").search(q, k)
    rerank_k = batched.resolve_rerank_k(cfg, k, None)

    # shortlist-hit fraction: queries whose int8 shortlist contains EVERY
    # row the exact fused stage returned — on those lanes pallas_q8 is
    # bit-identical to pallas by the re-rank invariance
    from repro.core.quantized import quantize_index

    store = quantize_index(searcher.index, cfg)
    _sld, sl_gidx = batched.q8_shortlist(
        searcher.index, store, cfg, q, rerank_k,
    )
    sl_ids = jnp.where(
        sl_gidx >= 0, jnp.take(searcher.index.ids_sorted, jnp.maximum(sl_gidx, 0)), -2
    )
    covered = jnp.all(
        jnp.any(fused.ids[:, :, None] == sl_ids[:, None, :], axis=-1)
        | ~fused.valid,
        axis=-1,
    )
    shortlist_hit_frac = float(jnp.mean(covered))

    backends = {}
    grid_exact = ("jnp", "pallas", "pallas_gather")
    for name in grid_exact + ("pallas_q8",):
        res = searcher.with_plan(backend=name).search(q, k)
        hit = jnp.any(res.ids[:, :, None] == truth.ids[:, None, :], axis=1)
        recall = float(jnp.sum(hit & truth.valid) / t_valid)
        parity = (
            bool(jnp.all(res.ids == fused.ids))
            if name in grid_exact else None
        )
        rec = {"recall_at_k": recall, "parity_vs_jnp": parity}
        if name == "pallas_q8":
            q8_hit = jnp.any(res.ids[:, :, None] == fused.ids[:, None, :],
                             axis=1)
            rec["recall_vs_pallas"] = float(
                jnp.sum(q8_hit & fused.valid) / jnp.maximum(jnp.sum(fused.valid), 1)
            )
            rec["shortlist_hit_frac"] = shortlist_hit_frac
        backends[name] = rec
        csv.row("quantized", n, 0, name, f"{recall:.3f}")

    # candidate-stage HBM bytes per batch, honest accounting: the q8 path
    # pays 1 byte/dim + a 4-byte scale per candidate row, PLUS the fp32
    # re-rank's second DMA of rerank_k rows; the fp32 fused path pays
    # 4 bytes/dim for every candidate row
    cand = cfg.window * cfg.row_cap
    fp32_bytes = b * cand * d * 4
    q8_bytes = b * (cand * (d + 4) + rerank_k * d * 4)
    reduction = fp32_bytes / q8_bytes
    csv.row("quantized", n, 0, "bytes_reduction", f"{reduction:.2f}x")

    return {
        "n": n, "batch": b, "k": k, "d": d, "rerank_k": rerank_k,
        "recall_floor": RECALL_FLOOR,
        "bytes_reduction_floor": BYTES_REDUCTION_FLOOR,
        "backends": backends,
        "candidate_bytes": {
            "fp32": fp32_bytes,
            "q8": q8_bytes,
            "reduction_x": reduction,
        },
    }


def main() -> None:
    csv = Csv("section,n,seed,variant,value")
    results = {
        "schema": 1, "timestamp": time.time(), "quick": _quick(),
        "paper": _paper_section(csv),
        "quantized": _quantized_section(csv),
    }
    art_dir = os.environ.get("REPRO_BENCH_ARTIFACTS", ".")
    path = os.path.join(art_dir, "BENCH_accuracy.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_accuracy] wrote {path}", flush=True)
    return csv


if __name__ == "__main__":
    main()
