"""Building blocks shared by every architecture: RMSNorm, RoPE, SwiGLU, inits.

Params are plain nested dicts of jax.Arrays (fp32 storage); compute casts to
bf16 (activations) with fp32 for norms/softmax accumulations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

ACT_DTYPE = jnp.bfloat16


def dense_init(key, shape, fan_in=None, scale=1.0):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = scale / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(jnp.float32)


def embed_init(key, shape):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * 0.02).astype(jnp.float32)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,) int32 -> cos/sin (..., head_dim//2) float32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., n_heads, head_dim); cos/sin broadcastable (..., 1, head_dim//2)."""
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, wi: jax.Array, wg: jax.Array, wo: jax.Array) -> jax.Array:
    """SwiGLU MLP: (..., d) with wi/wg (d, ff), wo (ff, d)."""
    h = jnp.einsum("...d,df->...f", x, wg.astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, wi.astype(x.dtype)).astype(jnp.float32))
    return jnp.einsum("...f,fd->...d", (g.astype(x.dtype) * h), wo.astype(x.dtype))


def init_mlp(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (d_model, d_ff)),
        "wg": dense_init(k2, (d_model, d_ff)),
        "wo": dense_init(k3, (d_ff, d_model)),
    }


def softmax_cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None
) -> jax.Array:
    """Mean token NLL.  logits (..., V) any float dtype; labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
