"""Architecture registry: --arch <id> resolves here."""

from __future__ import annotations

import importlib

_ARCH_MODULES = {
    "minitron-8b": "minitron_8b",
    "stablelm-12b": "stablelm_12b",
    "stablelm-3b": "stablelm_3b",
    "internlm2-1.8b": "internlm2_1_8b",
    "musicgen-medium": "musicgen_medium",
    "jamba-v0.1-52b": "jamba_52b",
    "dbrx-132b": "dbrx_132b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "xlstm-125m": "xlstm_125m",
    "internvl2-1b": "internvl2_1b",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE


def long_context_mode(name: str) -> str:
    """'native' | 'retrieval' | 'skip' — how this arch serves long_500k."""
    return _module(name).LONG_CONTEXT
