"""Quickstart: the paper's active search, end to end, in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's workflow (Figs. 1-2): rasterize 2-D points onto an
image, actively search a query's neighbors by adapting the radius (Eq. 1),
and classify by per-class counts — then sanity-check against exact kNN.

ONE handle serves every execution path: `ActiveSearcher` bundles the index
with an `ExecutionPlan` (backend, interpret, chunk_size), and `.with_plan()`
re-plans the same index onto another registered backend.
"""

import numpy as np
import jax.numpy as jnp

from repro.api import ActiveSearcher, ExecutionPlan, GridConfig, identity_projection

rng = np.random.default_rng(0)

# --- the data set: N 2-D points with 3 classes (paper §3) -------------------
N, K = 50_000, 11
points = jnp.asarray(rng.normal(size=(N, 2)), jnp.float32)
labels = jnp.asarray(rng.integers(0, 3, size=N), jnp.int32)

# --- build the "image": grid + per-class count pyramid + CSR buckets --------
cfg = GridConfig(
    grid_size=1024,   # the image resolution (paper used 3000x3000)
    n_classes=3,      # one count channel per class (paper §2)
    r0=16,            # initial radius, pixels (paper used 100)
    window=64,        # candidate gather window (cells)
    row_cap=64,
    k_slack=2.0,      # accept n in [k, 2k] then re-rank (production mode)
)
searcher = ActiveSearcher.build(
    points, labels=labels, cfg=cfg, proj=identity_projection(points)
)
print("index stats      :", {k_: v for k_, v in searcher.stats().items()
                             if k_ in ("n_points", "levels", "backend")})

# --- search: zoom around the query, not over the dataset --------------------
queries = jnp.asarray(rng.normal(size=(5, 2)), jnp.float32)
res = searcher.search(queries, K)             # batched active search (jnp plan)
print("neighbor ids[0]  :", np.asarray(res.ids[0]))
print("distances[0]     :", np.round(np.asarray(res.dists[0]), 4))
print("Eq.1 radius/iters:", np.asarray(res.radius), np.asarray(res.iters))

# --- same index, kernel-backed plan -----------------------------------------
# backend="pallas" runs the Eq.-1 loop on the level-scheduled
# kernels.tile_count_multilevel (one pallas_call per iteration counts every
# query from its own pyramid level), then ranks candidates with the FUSED
# kernels.csr_candidate_topk: window spans are scalar-prefetched and
# candidate rows stream straight from the CSR store into VMEM, so no
# (B, window*row_cap) intermediate is ever materialized (interpret-mode on
# CPU; compiles to Mosaic on TPU with REPRO_PALLAS_INTERPRET=0).  Results
# are identical to the jnp plan; chunk_size= streams big batches through
# fixed-shape kernel invocations without changing any result.
res_k = searcher.with_plan(backend="pallas").search(queries, K)
assert np.array_equal(np.asarray(res.ids), np.asarray(res_k.ids))
assert np.array_equal(np.asarray(res.dists), np.asarray(res_k.dists))
print("pallas plan      : identical ids/dists ✓")

# --- classify like the paper's Fig. 2 (argmax of per-class circle counts) ---
pred_paper = searcher.classify(queries, K, mode="paper")
pred_refined = searcher.classify(queries, K, mode="refined")
truth = searcher.with_plan(backend="exact").classify(queries, K)
print("paper-mode predictions :", np.asarray(pred_paper))
print("refined predictions    :", np.asarray(pred_refined))
print("exact kNN ground truth :", np.asarray(truth))

# --- the paper's property: query cost independent of N ----------------------
import time
plan = ExecutionPlan(backend="jnp")
for n in (10_000, 100_000, 1_000_000):
    pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    s_n = ActiveSearcher.build(pts, cfg=cfg, plan=plan,
                               proj=identity_projection(pts))
    s_n.search(queries, K).ids.block_until_ready()   # warm
    t0 = time.perf_counter()
    s_n.search(queries, K).ids.block_until_ready()
    print(f"N={n:>9,}: active search {1e3*(time.perf_counter()-t0):6.1f} ms")
