"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-scale quantization + error-feedback residual (1-bit-Adam
lineage): the residual carries quantization error into the next step, so the
*accumulated* update is unbiased and training curves track the uncompressed
run closely (tested in tests/test_optim.py).

Two integration points:
  * compress_grads(): pure transform (grad -> dequantized grad + new residual)
    used inside any train step to bound cross-pod gradient traffic.
  * compressed_psum(): shard_map building block — quantize, psum the int8
    payload (8x less ICI traffic than fp32), dequantize, apply error feedback.

The raw int8 round-trip (scale choice, clip, reconstruction) is the shared
codec in `repro.utils.quantize` — the same one the quantized candidate
store (`core/quantized.py`) uses — so the two paths can never drift; only
the error-feedback wrapper is optimizer-specific and lives here.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.utils.quantize import (
    dequantize as _dequantize,
    quantize_symmetric as _quantize,
    quantize_with_scale,
)


def compress_leaf(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One error-feedback compression round: returns (g_hat, new_err)."""
    gf = g.astype(jnp.float32) + err
    q, scale = _quantize(gf)
    g_hat = _dequantize(q, scale)
    return g_hat, gf - g_hat


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def compress_grads(grads: Any, err: Any) -> tuple[Any, Any]:
    out = jax.tree.map(compress_leaf, grads, err)
    g_hat = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g_hat, new_err


def compressed_psum(g: jax.Array, err: jax.Array, axis: str) -> tuple[jax.Array, jax.Array]:
    """Inside shard_map: all-reduce a gradient in int8 with error feedback.

    Traffic: 1 byte/elem int8 payload + one scalar scale psum, vs 4 bytes/elem
    for an fp32 psum."""
    gf = g.astype(jnp.float32) + err
    q, scale = _quantize(gf)
    # max-scale across replicas keeps the shared dequantization consistent
    scale = lax.pmax(scale, axis)
    q = quantize_with_scale(gf, scale)
    g_hat_local = _dequantize(q, scale)
    total = lax.psum(q.astype(jnp.int32), axis).astype(jnp.float32) * scale
    n = lax.psum(jnp.ones((), jnp.float32), axis)
    return total / n, gf - g_hat_local
