"""internvl2-1b [vlm] — InternViT + InternLM2 backbone (arXiv:2404.16821; hf).

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.  The InternViT
frontend is a STUB per the assignment: input_specs() provides precomputed
patch embeddings (B, 256, d) occupying the first 256 positions.
long_500k: SKIP (pure full attention)."""

from repro.models.config import ModelConfig, ParallelismPolicy

LONG_CONTEXT = "skip"

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    frontend="vision",
    n_frontend_tokens=256,
    # 14 heads -> pad to 16; vocab 151655 -> pad to 151808 (16*9488): the
    # unpadded CE materialized a replicated-on-vocab 20 GiB logits tensor
    # (EXPERIMENTS.md §Perf it. 3)
    policy=ParallelismPolicy(remat="full", scan_layers=True, accum=4,
                             pad_heads_to=16, pad_vocab_to=151808),
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    frontend="vision",
    n_frontend_tokens=8,
)
