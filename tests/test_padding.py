"""Head/vocab padding invariants: pad rows are dead weight — garbage in the
pad slots must not change any output, and pads never win argmax."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M
from repro.models.config import ParallelismPolicy


def _padded_cfg():
    base = get_smoke("internlm2-1.8b")   # 8 heads, kv 4, vocab 512
    return dataclasses.replace(
        base,
        policy=dataclasses.replace(
            base.policy, pad_heads_to=12, pad_kv_heads_to=6, pad_vocab_to=520
        ),
    )


def _poison_pads(params, cfg):
    """Overwrite pad-head / pad-vocab parameter rows with large garbage."""
    p = jax.tree.map(lambda a: a, params)  # shallow copy
    for blk in p["blocks"]:
        core = blk["core"]
        # stacked leading (R,) axis: wq (R, d, hq_eff, hd), wo (R, hq_eff, hd, d)
        core["wq"] = core["wq"].at[..., cfg.n_heads:, :].set(37.0)
        core["wo"] = core["wo"].at[:, cfg.n_heads:].set(37.0)
    p["embed"] = p["embed"].at[cfg.vocab_size:, :].set(37.0)
    p["lm_head"] = p["lm_head"].at[:, cfg.vocab_size:].set(37.0)
    return p


def test_pad_slots_do_not_affect_outputs(rng, key):
    cfg = _padded_cfg()
    params = M.init_params(key, cfg)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    }
    logits1, _ = M.forward(params, cfg, batch)
    logits2, _ = M.forward(_poison_pads(params, cfg), cfg, batch)
    np.testing.assert_allclose(
        np.asarray(logits1[..., : cfg.vocab_size].astype(jnp.float32)),
        np.asarray(logits2[..., : cfg.vocab_size].astype(jnp.float32)),
        atol=1e-3,
    )


def test_pad_vocab_never_wins_argmax(rng, key):
    cfg = _padded_cfg()
    params = M.init_params(key, cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    logits, _, _ = M.prefill(params, cfg, batch, cache_len=18)
    assert logits.shape[-1] == cfg.vocab_eff == 520
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size


def test_pad_heads_get_zero_gradient(rng, key):
    cfg = _padded_cfg()
    params = M.init_params(key, cfg)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    }
    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch)[0])(params)
    for blk in grads["blocks"]:
        gq = np.asarray(blk["core"]["wq"])           # (R, d, hq_eff, hd)
        assert np.abs(gq[..., cfg.n_heads:, :]).max() == 0.0
        go = np.asarray(blk["core"]["wo"])           # (R, hq_eff, hd, d)
        assert np.abs(go[:, cfg.n_heads:]).max() == 0.0
    ge = np.asarray(grads["embed"])
    assert np.abs(ge[cfg.vocab_size:]).max() == 0.0


def test_padded_train_loss_finite_and_decreasing(rng, key):
    from repro.optim import adamw
    cfg = _padded_cfg()
    params = M.init_params(key, cfg)
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    }

    @jax.jit
    def step(params, opt):
        (loss, _), g = jax.value_and_grad(M.loss_fn, has_aux=True)(params, cfg, batch)
        params, opt, _ = adamw.update(ocfg, g, opt, params)
        return params, opt, loss

    losses = []
    for _ in range(3):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]
