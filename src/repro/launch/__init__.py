"""Launch layer: production mesh, jitted step factories, dry-run, drivers."""
