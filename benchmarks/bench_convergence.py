"""Eq. 1 radius-iteration behaviour: convergence rate, iteration counts, the
effect of r0 (the paper observes r0=100 'seems too small' for sparse data —
time grows as the radius walks out), and the ISSUE-6 adaptive schedule:
per-query pyramid-seeded start radii + masked early exit.

Artifacts land in BENCH_convergence.json (REPRO_BENCH_ARTIFACTS dir):
  adaptive.baseline / early_exit / adaptive — converged_frac, mean/p99
  iters, iterations_saved and tile_dmas_skipped vs the always-on fixed-r0
  schedule, plus the parity flags render_bench_table.py --check gates on
  (the schedule must stay bit-identical to the jnp oracle, and the adaptive
  seed must actually REDUCE mean iterations on the skewed-density config).

Env knobs:
  REPRO_BENCH_QUICK=1      shrink sweeps to CI-friendly sizes
  REPRO_BENCH_ARTIFACTS=D  directory for BENCH_convergence.json (default ".")
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Csv, paper_data
from repro.core import batched
from repro.core import pyramid as pyr
from repro.core import projection as proj_lib
from repro.core.grid import GridConfig, build_index
from repro.core.projection import identity_projection

K = 11


def _quick() -> bool:
    return os.environ.get("REPRO_BENCH_QUICK", "0") == "1"


def _schedule_stats(stats) -> dict:
    it = np.asarray(stats["iters"], np.float64)
    return {
        "converged_frac": float(np.mean(np.asarray(stats["converged"],
                                                   np.float64))),
        "mean_iters": float(it.mean()),
        "p99_iters": float(np.percentile(it, 99)),
        "mean_radius": float(np.mean(np.asarray(stats["radius"], np.float64))),
    }


def _stats_match(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(a[key]), np.asarray(b[key]))
        for key in ("radius", "count", "iters", "converged")
    )


def bench_adaptive(rng, csv: Csv) -> dict:
    """The ISSUE-6 headline numbers on a skewed-density set: clusters of very
    different spread + sparse background, global r0 deliberately tuned to
    NONE of them (the paper's fixed-r0 failure mode).  Three variants of the
    SAME batched loop:

      baseline   — fixed r0, always-on counting (the pre-ISSUE-6 schedule)
      early_exit — fixed r0, converged lanes skip their tile DMAs
      adaptive   — pyramid-seeded per-query r0 + early exit

    Schedules are bit-identical between baseline and early_exit (the mask
    only elides work), so iterations_saved comes entirely from the adaptive
    seed; tile_dmas_skipped counts the 2x2-cover DMAs the mask elided.
    """
    b = 32 if _quick() else 64
    pts = np.concatenate([
        rng.normal([2, 2, 0, 0], 0.15, size=(600, 4)),
        rng.normal([-2, -2, 0, 0], 0.8, size=(400, 4)),
        rng.uniform(-4, 4, size=(200, 4)),
    ]).astype(np.float32)
    cfg = GridConfig(grid_size=256, tile=16, window=48, row_cap=48, r0=200,
                     k_slack=3.0)
    pts_j = jnp.asarray(pts)
    proj = proj_lib.pca_projection(pts_j, grid_dim=2)
    index = build_index(pts_j, cfg, proj)
    q = jnp.asarray(pts[rng.choice(len(pts), b, replace=False)])
    qg = proj_lib.to_grid_coords(proj, q, cfg.grid_size)

    base = batched.radius_search_batched(index, cfg, qg, K, early_exit=False)
    early = batched.radius_search_batched(index, cfg, qg, K, early_exit=True)
    adapt = batched.radius_search_batched(index, cfg, qg, K,
                                          adaptive_r0=True, early_exit=True)
    oracle = jax.vmap(
        lambda g: pyr.radius_search(index, cfg, g, K, adaptive_r0=True)
    )(qg)

    # DMA accounting: the always-on loop issues 4 cover-tile DMAs per lane
    # per loop iteration (the loop runs max-lane-iters times) + 4 per lane
    # for the full post-loop recount
    loop_iters = int(np.asarray(base["iters"]).max())
    always_on_dmas = 4 * b * loop_iters + 4 * b
    skipped = int(adapt["tile_dmas_skipped"])
    iters_saved = int(np.asarray(base["iters"]).sum()
                      - np.asarray(adapt["iters"]).sum())

    out = {
        "config": {
            "batch": b, "k": K, "grid_size": cfg.grid_size,
            "tile": cfg.tile, "r0": cfg.r0, "k_slack": cfg.k_slack,
        },
        "baseline": _schedule_stats(base),
        "early_exit": {
            **_schedule_stats(early),
            "tile_dmas_skipped": int(early["tile_dmas_skipped"]),
        },
        "adaptive": {
            **_schedule_stats(adapt),
            "tile_dmas_skipped": skipped,
        },
        "mean_iters_reduction": (
            _schedule_stats(base)["mean_iters"]
            - _schedule_stats(adapt)["mean_iters"]
        ),
        "iterations_saved": iters_saved,
        "always_on_tile_dmas": always_on_dmas,
        "tile_dmas_skipped_frac": skipped / always_on_dmas,
        # early exit must not change the schedule; the adaptive batched loop
        # must match the vmapped jnp oracle lane for lane
        "parity_early_exit_vs_baseline": _stats_match(early, base),
        "parity_adaptive_vs_jnp_oracle": _stats_match(adapt, oracle),
    }
    csv.row("adaptive_baseline", f"B={b} r0={cfg.r0}",
            f"{out['baseline']['converged_frac']:.3f}",
            f"{out['baseline']['mean_iters']:.2f}",
            f"{out['baseline']['mean_radius']:.1f}", "-")
    csv.row("adaptive_early_exit", f"B={b} r0={cfg.r0}",
            f"{out['early_exit']['converged_frac']:.3f}",
            f"{out['early_exit']['mean_iters']:.2f}",
            f"{out['early_exit']['mean_radius']:.1f}",
            out["early_exit"]["tile_dmas_skipped"])
    csv.row("adaptive_seeded", f"B={b} seeded",
            f"{out['adaptive']['converged_frac']:.3f}",
            f"{out['adaptive']['mean_iters']:.2f}",
            f"{out['adaptive']['mean_radius']:.1f}", skipped)
    print(f"[bench_convergence] adaptive schedule: mean iters "
          f"{out['baseline']['mean_iters']:.2f} -> "
          f"{out['adaptive']['mean_iters']:.2f} "
          f"({iters_saved} iterations saved), "
          f"{skipped}/{always_on_dmas} tile DMAs skipped "
          f"({out['tile_dmas_skipped_frac']:.0%})", flush=True)
    return out


def main(n=None, r0s=None) -> None:
    rng = np.random.default_rng(0)
    n = n or (5_000 if _quick() else 20_000)
    r0s = r0s or ((8, 100) if _quick() else (2, 8, 32, 100, 400))
    grid = 256 if _quick() else 1024
    pts, labels = paper_data(rng, n)
    q, _ = paper_data(rng, 50 if _quick() else 200)
    csv = Csv("r0,converged_frac,mean_iters,mean_radius,mean_count")
    sweep = []
    for r0 in r0s:
        cfg = GridConfig(grid_size=grid, tile=16, n_classes=3, window=64,
                         row_cap=64, r0=r0, k_slack=2.0)
        idx = build_index(pts, cfg, identity_projection(pts), labels=labels)

        def stats_of(one_q):
            qg = proj_lib.to_grid_coords(idx.proj, one_q, cfg.grid_size)
            return pyr.radius_search(idx, cfg, qg, K)

        stats = jax.vmap(stats_of)(q)
        row = {
            "r0": r0,
            "converged_frac": float(
                jnp.mean(stats["converged"].astype(jnp.float32))
            ),
            "mean_iters": float(jnp.mean(stats["iters"].astype(jnp.float32))),
            "mean_radius": float(
                jnp.mean(stats["radius"].astype(jnp.float32))
            ),
            "mean_count": float(jnp.mean(stats["count"].astype(jnp.float32))),
        }
        sweep.append(row)
        csv.row(
            r0,
            f"{row['converged_frac']:.3f}",
            f"{row['mean_iters']:.2f}",
            f"{row['mean_radius']:.1f}",
            f"{row['mean_count']:.1f}",
        )

    csv2 = Csv("variant,config,converged_frac,mean_iters,mean_radius,"
               "tile_dmas_skipped")
    adaptive = bench_adaptive(rng, csv2)

    results = {
        "schema": 1,
        "timestamp": time.time(),
        "quick": _quick(),
        "r0_sweep": sweep,
        "adaptive": adaptive,
    }
    art_dir = os.environ.get("REPRO_BENCH_ARTIFACTS", ".")
    path = os.path.join(art_dir, "BENCH_convergence.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_convergence] wrote {path}", flush=True)
    return csv


if __name__ == "__main__":
    main()
