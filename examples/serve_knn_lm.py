"""Serving with the paper's technique as a first-class feature: a kNN-LM head
whose datastore is searched with ACTIVE SEARCH at every decode step.

  PYTHONPATH=src python examples/serve_knn_lm.py

Demonstrates the measurable effect of retrieval: after training briefly on a
deterministic bigram corpus, the kNN datastore (memorizing exact continuations)
sharpens next-token predictions on held-out text from the same chain —
held-out NLL improves vs the plain LM head (the margin grows with datastore
coverage and model quality; at this demo scale it is small but consistent).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core import knn_lm
from repro.data.pipeline import DataConfig, synth_batch
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Engine, ServeConfig, build_datastore_from_model
from repro.launch.train import TrainConfig, train_loop
from repro.models import model as M


def nll_of(logp, labels):
    gold = np.take_along_axis(np.asarray(logp), np.asarray(labels)[:, None], 1)
    return float(-gold.mean())


def main():
    cfg = get_smoke("internlm2-1.8b")
    mesh = make_host_mesh(1, 1)

    # 1. train until the LM has learned the chain (hidden states then
    # separate contexts, which is what the datastore keys index)
    print("[example] training 400 steps on the Markov-chain corpus ...")
    out = train_loop(cfg, TrainConfig(steps=400, batch=8, seq=64, log_every=100,
                                      lr=1e-3), mesh)
    params = out["state"]["params"]

    # 2. harvest the datastore from the model's own prefill pass
    dc = DataConfig(global_batch=16, seq_len=64, vocab_size=cfg.vocab_size, seed=7)
    corpus = np.concatenate(
        [synth_batch(dc, s)["tokens"] for s in range(8)], axis=0
    )
    knn_cfg = knn_lm.KNNLMConfig(k=8, lam=0.3)
    store = build_datastore_from_model(cfg, params, corpus, knn_cfg)
    print(f"[example] datastore: {store.n_points} (hidden -> next-token) pairs")

    # 3. held-out evaluation: same chain, unseen step indices
    held = synth_batch(dataclasses_replace_seed(dc, 7), 999)
    tokens = jnp.asarray(held["tokens"][:8])
    logits, _, hidden = M.prefill(params, cfg, {"tokens": tokens[:, :-1]},
                                  cache_len=tokens.shape[1])
    labels = tokens[:, -1]

    lm_logp = jax.nn.log_softmax(logits, axis=-1)
    knn_logp = knn_lm.knn_lm_logits(store, knn_cfg, hidden.astype(jnp.float32),
                                    logits)
    print(f"[example] held-out NLL  plain LM: {nll_of(lm_logp, labels):.4f}")
    print(f"[example] held-out NLL  kNN-LM  : {nll_of(knn_logp, labels):.4f}")

    # 4. batched generation through the serving engine
    engine = Engine(cfg, params, mesh,
                    ServeConfig(knn=knn_cfg, max_new_tokens=16), datastore=store)
    prompts = np.asarray(tokens[:4, :16])
    toks, _ = engine.generate(prompts)
    s = engine.stats
    print(f"[example] generated {toks.shape}; decode "
          f"{s['tokens']/max(s['decode_s'],1e-9):.1f} tok/s")


def dataclasses_replace_seed(dc, seed):
    import dataclasses
    return dataclasses.replace(dc, seed=seed)


if __name__ == "__main__":
    main()
