"""Exact brute-force kNN — the paper's comparator ("original kNN").

Blocked over the datastore so memory stays bounded at any N: a lax.scan over
N-chunks keeps a running top-k per query (the same streaming-top-k pattern the
kernels/brute_knn Pallas kernel uses on TPU).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class ExactResult(NamedTuple):
    ids: jax.Array    # (B, k) int32
    dists: jax.Array  # (B, k) float32


def _pairwise(q: jax.Array, x: jax.Array, metric: str) -> jax.Array:
    """(B, d) x (N, d) -> (B, N) distances."""
    if metric == "l1":
        return jnp.sum(jnp.abs(q[:, None, :] - x[None, :, :]), axis=-1)
    # ||q - x||^2 = ||q||^2 - 2 q.x + ||x||^2  (MXU-friendly form)
    qq = jnp.sum(q * q, axis=-1, keepdims=True)
    xx = jnp.sum(x * x, axis=-1)
    d2 = qq - 2.0 * (q @ x.T) + xx[None, :]
    return jnp.sqrt(jnp.maximum(d2, 0.0))


@partial(jax.jit, static_argnames=("k", "metric", "block"))
def knn(
    queries: jax.Array,
    points: jax.Array,
    k: int,
    metric: str = "l2",
    block: int = 4096,
) -> ExactResult:
    """Exact kNN of `queries` (B, d) against `points` (N, d)."""
    q = queries.astype(jnp.float32)
    x = points.astype(jnp.float32)
    b, _ = q.shape
    n = x.shape[0]

    if n <= block:
        d = _pairwise(q, x, metric)
        neg, idx = lax.top_k(-d, min(k, n))
        if k > n:  # pad to k
            padd = jnp.full((b, k - n), jnp.inf, jnp.float32)
            padi = jnp.full((b, k - n), -1, jnp.int32)
            return ExactResult(
                jnp.concatenate([idx.astype(jnp.int32), padi], axis=1),
                jnp.concatenate([-neg, padd], axis=1),
            )
        return ExactResult(idx.astype(jnp.int32), -neg)

    # streaming top-k over blocks
    nb = -(-n // block)
    n_pad = nb * block
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    xb = xp.reshape(nb, block, -1)

    def step(carry, inp):
        best_d, best_i = carry
        blk, off = inp
        d = _pairwise(q, blk, metric)                       # (B, block)
        ids = off + jnp.arange(block, dtype=jnp.int32)
        d = jnp.where(ids[None, :] < n, d, jnp.inf)
        cat_d = jnp.concatenate([best_d, d], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, (b, block))], axis=1)
        neg, sel = lax.top_k(-cat_d, k)
        return (-neg, jnp.take_along_axis(cat_i, sel, axis=1)), None

    init = (jnp.full((b, k), jnp.inf, jnp.float32), jnp.full((b, k), -1, jnp.int32))
    offs = (jnp.arange(nb, dtype=jnp.int32) * block)
    (best_d, best_i), _ = lax.scan(step, init, (xb, offs))
    return ExactResult(best_i, best_d)


@partial(jax.jit, static_argnames=("k", "n_classes", "metric", "block"))
def classify(
    queries: jax.Array,
    points: jax.Array,
    labels: jax.Array,
    k: int,
    n_classes: int,
    metric: str = "l2",
    block: int = 4096,
) -> jax.Array:
    """Exact kNN majority-vote classification — the paper's ground truth."""
    res = knn(queries, points, k, metric=metric, block=block)
    neigh = labels[jnp.clip(res.ids, 0, labels.shape[0] - 1)]
    onehot = jax.nn.one_hot(neigh, n_classes, dtype=jnp.float32)
    votes = jnp.sum(onehot * jnp.isfinite(res.dists)[..., None], axis=1)
    return jnp.argmax(votes, axis=-1).astype(jnp.int32)
