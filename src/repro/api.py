"""`repro.api` — the public searcher API (thin re-export of core/engine.py).

  from repro import api
  s = api.ActiveSearcher.build(points, labels=labels,
                               cfg=api.GridConfig(n_classes=3),
                               plan=api.ExecutionPlan(backend="pallas"))
  res = s.search(queries, k=11)
"""

from repro.core.engine import (
    ActiveSearcher,
    BackendImpl,
    ExecutionPlan,
    SearchResult,
    get_backend,
    register_backend,
    registered_backends,
)
from repro.core.grid import GridConfig, GridIndex, build_index
from repro.core.projection import (
    Projection,
    gaussian_projection,
    identity_projection,
    pca_projection,
)

__all__ = [
    "ActiveSearcher",
    "BackendImpl",
    "ExecutionPlan",
    "SearchResult",
    "get_backend",
    "register_backend",
    "registered_backends",
    "GridConfig",
    "GridIndex",
    "build_index",
    "Projection",
    "identity_projection",
    "gaussian_projection",
    "pca_projection",
]
