"""Paper §2 resolution trade-off: accuracy and query time vs grid_size.
'If the resolution increases, the algorithm requires a bigger memory size and
has to check more pixels' — we measure both directions."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Csv, paper_data, timeit
from repro.api import ActiveSearcher, GridConfig, identity_projection
from repro.core import exact

K, N = 11, 20_000


def main(grids=(128, 256, 512, 1024, 2048)) -> None:
    rng = np.random.default_rng(0)
    pts, labels = paper_data(rng, N)
    q, _ = paper_data(rng, 100)
    truth = exact.classify(q, pts, labels, K, 3)
    csv = Csv("grid_size,accuracy,query_s,index_mib")

    for g in grids:
        cfg = GridConfig(grid_size=g, tile=16, n_classes=3, window=64,
                         row_cap=64, r0=max(g // 30, 2), k_slack=2.0)
        searcher = ActiveSearcher.build(
            pts, labels=labels, cfg=cfg, proj=identity_projection(pts)
        )
        pred = searcher.classify(q, K)
        acc = float(np.mean(np.asarray(pred) == np.asarray(truth)))
        t = timeit(lambda: searcher.classify(q, K), repeats=3)
        idx = searcher.index
        mib = sum(a.size * a.dtype.itemsize for a in
                  [idx.offsets, *idx.pyramid]) / 2**20
        csv.row(g, f"{acc:.3f}", f"{t:.4f}", f"{mib:.1f}")
    return csv


if __name__ == "__main__":
    main()
