"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE every other
layer, 16 experts top-2 (arXiv:2403.19887; hf).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.
long_500k: NATIVE (attention is 4/32 layers; Mamba state is O(1)/token)."""

from repro.models.config import (
    MambaConfig,
    ModelConfig,
    MoEConfig,
    ParallelismPolicy,
)

LONG_CONTEXT = "native"

# Jamba block: period 8, attention at in-block index 4, MoE on odd layers.
_PATTERN = tuple("mamba" if i != 4 else "attn" for i in range(8))
_MOE = tuple(i % 2 == 1 for i in range(8))

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    block_period=8,
    pattern=_PATTERN,
    moe_layers=_MOE,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, group_size=512),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    # accum=16: the mamba chunk tensors (B,Q,din,ds) dominate temp memory;
    # halving the microbatch brings 26.6 -> inside 16 GiB HBM.
    policy=ParallelismPolicy(remat="full", scan_layers=True, accum=16),
)

SMOKE = ModelConfig(
    name="jamba-52b-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    block_period=8,
    pattern=_PATTERN,
    moe_layers=_MOE,
    # capacity_factor 4: drop-free at smoke scale (prefill/decode consistency)
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, group_size=64,
                  capacity_factor=4.0),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, chunk=16),
)
