"""Fault-tolerance runtime: preemption handling, straggler detection,
restart-with-backoff.  Single-controller JAX semantics: the coordinator makes
all decisions; workers follow the compiled program.

Pieces:
  * PreemptionGuard — SIGTERM/SIGINT -> drain flag; the train loop checkpoints
    and exits cleanly at the next step boundary (cluster eviction contract).
  * StepTimer — EWMA step-time model + straggler flags.  On a real pod a
    straggler shows up as a slow step for EVERYONE (SPMD lockstep), so the
    mitigation is coordinator-side: flag, log, and (if persistent) request a
    re-slice — here that surfaces as `should_reshard()`.
  * run_with_restarts — supervisor that restarts the step loop from the last
    checkpoint on failure with exponential backoff (node-failure recovery;
    exercised in tests with injected faults).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable


class PreemptionGuard:
    """SIGTERM/SIGINT -> drain.  Use as a context manager around the loop."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._signals = signals
        self._old = {}
        self.draining = False

    def _handler(self, signum, frame):
        self.draining = True

    def __enter__(self):
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, old in self._old.items():
            signal.signal(s, old)
        return False


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    ewma: float
    is_straggler: bool


class StepTimer:
    """EWMA step-time tracker; a step > `threshold` x EWMA is a straggler."""

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0, warmup: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self.ewma: float | None = None
        self.count = 0
        self.straggler_steps: list[int] = []
        self._consecutive = 0

    def record(self, step: int, seconds: float) -> StepStats:
        self.count += 1
        if self.ewma is None:
            self.ewma = seconds
        straggler = (
            self.count > self.warmup and seconds > self.threshold * self.ewma
        )
        if straggler:
            self.straggler_steps.append(step)
            self._consecutive += 1
        else:
            self._consecutive = 0
            # stragglers are excluded from the EWMA (they are anomalies)
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return StepStats(step, seconds, self.ewma, straggler)

    def should_reshard(self, patience: int = 5) -> bool:
        """Persistent slowness -> the coordinator should drop/replace the slow
        host and resume on a smaller mesh (elastic path, checkpoint/store.py)."""
        return self._consecutive >= patience


def run_with_restarts(
    make_loop: Callable[[], int],
    max_restarts: int = 3,
    backoff_s: float = 0.5,
    on_restart: Callable[[int, Exception], None] | None = None,
) -> int:
    """Supervisor: run `make_loop()` (returns final step); on exception,
    restart (the loop re-resolves its start step from the checkpoint store).
    """
    attempt = 0
    while True:
        try:
            return make_loop()
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempt, e)
            time.sleep(backoff_s * (2 ** (attempt - 1)))
