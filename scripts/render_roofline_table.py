"""Render the §Roofline markdown table from the dry-run artifact into
EXPERIMENTS.md (replaces the placeholder/previous table between markers)."""

import json
import re
import sys

SINGLE = "runs/dryrun_single_v3.jsonl"
BEGIN = "<!-- ROOFLINE TABLE BEGIN -->"
END = "<!-- ROOFLINE TABLE END -->"


def load(path):
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_ms(x):
    return f"{x*1e3:,.1f}"


def main():
    recs = load(SINGLE)
    rows = [
        "| arch | shape | kind | compute ms | memory ms (fused) | collective ms "
        "| bottleneck | 6ND/HLO | temp GiB | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(recs.items()):
        if str(r.get("status", "")).startswith("SKIP"):
            rows.append(
                f"| {arch} | {shape} | — | — | — | — | — | — | — | "
                "SKIP: pure full attention |"
            )
            continue
        if r.get("status") != "OK":
            rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | — | {r['status']} |")
            continue
        roof = r["roofline"]
        mem = (r.get("memory") or {}).get("temp_size_in_bytes", 0) / 2**30
        note = "e2e active-search retrieval" if r.get("retrieval") else ""
        ratio = r.get("model_flops_ratio") or 0
        rows.append(
            f"| {arch} | {shape} | {r['kind']} | {fmt_ms(roof['compute_s'])} "
            f"| {fmt_ms(roof['memory_s'])} | {fmt_ms(roof['collective_s'])} "
            f"| {roof['bottleneck']} | {ratio:.3f} | {mem:.2f} | {note} |"
        )
    table = "\n".join(rows)

    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    block = f"{BEGIN}\n{table}\n{END}"
    if BEGIN in doc:
        doc = re.sub(
            re.escape(BEGIN) + r".*?" + re.escape(END), block, doc, flags=re.S
        )
    else:
        doc = doc.replace(
            "**(table below inserted from runs/dryrun_single_v3.jsonl)**", block
        )
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print(f"rendered {len(recs)} cells into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
