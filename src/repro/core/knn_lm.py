"""kNN-LM head: the paper's retrieval primitive as a production LM feature.

Khandelwal-style kNN-LM: a datastore maps hidden states h_t -> next token
y_{t+1}.  At serve time the LM distribution is interpolated with a kNN
distribution obtained by active search over the datastore:

    p(y) = lam * p_knn(y) + (1 - lam) * p_lm(y)
    p_knn(y)  propto  sum_{(h_i, y_i) in topk(h)} 1[y_i = y] * exp(-d(h, h_i) / T)

The datastore rides in GridIndex.labels_sorted (token ids are per-point
payloads, NOT class channels — the grid itself stays single-channel, so vocab
size never touches grid memory).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.engine import ActiveSearcher, ExecutionPlan
from repro.core.grid import GridConfig, GridIndex, build_index
from repro.core.projection import Projection, pca_projection


@dataclasses.dataclass(frozen=True)
class KNNLMConfig:
    k: int = 16
    lam: float = 0.25        # interpolation weight on the kNN distribution
    temperature: float = 1.0  # distance softmax temperature
    # HOW datastore searches execute (backend, interpret, chunked streaming)
    # — one ExecutionPlan instead of loose backend=/chunk_size= fields
    plan: ExecutionPlan = ExecutionPlan()
    grid: GridConfig = dataclasses.field(
        default_factory=lambda: GridConfig(
            grid_size=1024, tile=16, window=32, row_cap=32, r0=8, k_slack=4.0
        )
    )


def build_datastore(
    keys: jax.Array, next_tokens: jax.Array, cfg: KNNLMConfig, proj: Projection | None = None
) -> GridIndex:
    """keys: (N, d) hidden states; next_tokens: (N,) int32 payload tokens."""
    if proj is None:
        proj = pca_projection(keys, grid_dim=2)
    return build_index(keys, cfg.grid, proj, labels=next_tokens.astype(jnp.int32))


def extend_datastore(
    index: GridIndex, cfg: KNNLMConfig, keys: jax.Array, next_tokens: jax.Array
) -> GridIndex:
    """Grow the datastore ONLINE with fresh (hidden, next-token) pairs.

    Serving harvests these from its own decode stream (`launch/serve.py
    --knn-online`): the new keys are projected with the datastore's EXISTING
    projection (no PCA re-fit — keys far outside the fitted extents clamp to
    the grid edge, which active search tolerates) and delta-applied via
    `core.mutable` instead of rebuilding the index.

    This one-shot helper re-opens the slack layout each call; a caller that
    grows REPEATEDLY should hold the state across batches instead (an
    `ActiveSearcher` handle via `.insert`, or a `core.mutable.MutableIndex`
    directly, as serve's Engine does)."""
    from repro.core import mutable as mut

    state = mut.from_index(index, cfg.grid)
    state = mut.insert(
        state, cfg.grid, keys, labels=jnp.asarray(next_tokens, jnp.int32)
    )
    return mut.snapshot(state, cfg.grid)


@partial(jax.jit, static_argnames=("cfg", "vocab_size"))
def knn_logprobs(
    index: GridIndex, cfg: KNNLMConfig, hidden: jax.Array, vocab_size: int
) -> jax.Array:
    """log p_knn over the vocab.  hidden: (B, d) -> (B, vocab)."""
    searcher = ActiveSearcher.from_index(index, cfg.grid, plan=cfg.plan)
    res = searcher.search(hidden, cfg.k, mode="refined")
    w = jnp.where(res.valid, -res.dists / cfg.temperature, -jnp.inf)
    w = jax.nn.softmax(w, axis=-1)                    # (B, k)
    w = jnp.where(res.valid, w, 0.0)
    tok = jnp.clip(res.labels, 0, vocab_size - 1)

    def scatter(wi, ti):
        return jnp.zeros((vocab_size,), jnp.float32).at[ti].add(wi)

    p = jax.vmap(scatter)(w, tok)                     # (B, vocab)
    # A query can retrieve NOTHING (sparse datastore, empty candidate
    # window): softmax over all -inf is nan and the scatter leaves p == 0.
    # No evidence -> the uninformative distribution, so p_knn stays a
    # normalized distribution for every lane and interpolation stays finite.
    any_valid = jnp.any(res.valid, axis=-1, keepdims=True)
    p = jnp.where(any_valid, p, 1.0 / vocab_size)
    return jnp.log(jnp.maximum(p, 1e-20))


@partial(jax.jit, static_argnames=("cfg",))
def interpolate(
    lm_logits: jax.Array, knn_logp: jax.Array, cfg: KNNLMConfig
) -> jax.Array:
    """log( lam * p_knn + (1-lam) * p_lm ), numerically via logaddexp."""
    lm_logp = jax.nn.log_softmax(lm_logits, axis=-1)
    return jnp.logaddexp(
        jnp.log(cfg.lam) + knn_logp, jnp.log1p(-cfg.lam) + lm_logp
    )


def knn_lm_logits(
    index: GridIndex, cfg: KNNLMConfig, hidden: jax.Array, lm_logits: jax.Array
) -> jax.Array:
    """One-call API used by serve/engine.py."""
    knn_lp = knn_logprobs(index, cfg, hidden, lm_logits.shape[-1])
    return interpolate(lm_logits, knn_lp, cfg)
