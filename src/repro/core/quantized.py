"""QuantizedStore: the CSR candidate store at int8 width (pallas_q8).

The fused candidate kernel is bandwidth-bound on its row DMAs — every
window row moves `row_cap * d` float32s from HBM per query.  This module
holds the SAME CSR-sorted points at 1 byte/dim with per-cell symmetric
scales (`repro.utils.quantize`, the codec shared with the gradient
compressor):

  cell_scales[c] = max(|x|) over points of cell c / 127     (eps-floored)
  q_points[j]    = clip(round(points_sorted[j] / scale_of_cell(j)))

Per-CELL scales — not per-tensor — because a cell is the locality unit of
active search: points that share a bucket are close in the projected plane
and typically similar in magnitude, so the codebook adapts to local range
instead of paying the global max everywhere.  `row_scales` broadcasts the
owning cell's scale to every CSR row (including the `padded_csr` slack
rows, which quantize to zeros under the eps floor) so the kernel can DMA a
`(row_cap, 1)` scale slice alongside each `(row_cap, d)` int8 row slice —
span arithmetic stays identical to the fp32 store.

The store is DERIVED: `quantize_index` is a pure function of a
`GridIndex`, and `mutable.snapshot` reproduces `build_index`'s CSR order
bit-for-bit, so requantizing after insert/delete yields the exact store a
from-scratch rebuild would (the mutability invariant extends to the
quantized path for free — `mutable.quantized_snapshot` packages that, and
tests/test_quantized.py pins it).  The engine memoizes the store per
handle (`core/engine.py`), and every mutation returns a new handle, so the
memo can never serve a stale store.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.active_search import padded_csr
from repro.core.grid import GridConfig, GridIndex, cell_id_of
from repro.utils.quantize import quantize_with_scale, symmetric_scale


class QuantizedStore(NamedTuple):
    """int8 view of the padded CSR point store (same row order/indices)."""

    q_points: jax.Array    # (n_pad, d) int8 — CSR-sorted points, quantized
    row_scales: jax.Array  # (n_pad, 1) float32 — owning cell's scale per row
    cell_scales: jax.Array  # (padded_size**2,) float32 — per-cell scale


@partial(jax.jit, static_argnames=("cfg",))
def quantize_index(index: GridIndex, cfg: GridConfig) -> QuantizedStore:
    """Per-cell symmetric int8 quantization of the padded CSR store.

    jit-able; the only data dependencies are the CSR arrays, so the result
    is a pure function of the snapshot (bit-identical stores for
    bit-identical indexes — the property the mutable path relies on).
    """
    pts, _crd, _lab, _ids, _n, n_pad = padded_csr(index, cfg.row_cap)
    g = cfg.padded_size
    n = index.points_sorted.shape[0]

    cid = cell_id_of(index.coords_sorted, g)                      # (n,)
    point_max = jnp.max(jnp.abs(index.points_sorted), axis=1)     # (n,)
    cell_max = jax.ops.segment_max(
        point_max, cid, num_segments=g * g, indices_are_sorted=True
    )
    # empty cells come back -inf; floor them so the scale stays finite
    cell_scales = symmetric_scale(jnp.maximum(cell_max, 0.0))     # (g*g,)

    row_scales = cell_scales[cid]                                 # (n,)
    if n_pad != n:  # padded_csr slack rows: eps scale, zero codes
        row_scales = jnp.concatenate(
            [row_scales, jnp.full((n_pad - n,), symmetric_scale(0.0))]
        )
    row_scales = row_scales[:, None].astype(jnp.float32)          # (n_pad, 1)
    return QuantizedStore(
        q_points=quantize_with_scale(pts, row_scales),
        row_scales=row_scales,
        cell_scales=cell_scales,
    )
