"""Head/vocab padding invariants: pad rows are dead weight — garbage in the
pad slots must not change any output, and pads never win argmax."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import model as M
from repro.models.config import ParallelismPolicy


def _padded_cfg():
    base = get_smoke("internlm2-1.8b")   # 8 heads, kv 4, vocab 512
    return dataclasses.replace(
        base,
        policy=dataclasses.replace(
            base.policy, pad_heads_to=12, pad_kv_heads_to=6, pad_vocab_to=520
        ),
    )


def _poison_pads(params, cfg):
    """Overwrite pad-head / pad-vocab parameter rows with large garbage."""
    p = jax.tree.map(lambda a: a, params)  # shallow copy
    for blk in p["blocks"]:
        core = blk["core"]
        # stacked leading (R,) axis: wq (R, d, hq_eff, hd), wo (R, hq_eff, hd, d)
        core["wq"] = core["wq"].at[..., cfg.n_heads:, :].set(37.0)
        core["wo"] = core["wo"].at[:, cfg.n_heads:].set(37.0)
    p["embed"] = p["embed"].at[cfg.vocab_size:, :].set(37.0)
    p["lm_head"] = p["lm_head"].at[:, cfg.vocab_size:].set(37.0)
    return p


def test_pad_slots_do_not_affect_outputs(rng, key):
    cfg = _padded_cfg()
    params = M.init_params(key, cfg)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    }
    logits1, _ = M.forward(params, cfg, batch)
    logits2, _ = M.forward(_poison_pads(params, cfg), cfg, batch)
    np.testing.assert_allclose(
        np.asarray(logits1[..., : cfg.vocab_size].astype(jnp.float32)),
        np.asarray(logits2[..., : cfg.vocab_size].astype(jnp.float32)),
        atol=1e-3,
    )


def test_pad_vocab_never_wins_argmax(rng, key):
    cfg = _padded_cfg()
    params = M.init_params(key, cfg)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)}
    logits, _, _ = M.prefill(params, cfg, batch, cache_len=18)
    assert logits.shape[-1] == cfg.vocab_eff == 520
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size


def test_pad_heads_get_zero_gradient(rng, key):
    cfg = _padded_cfg()
    params = M.init_params(key, cfg)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    }
    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch)[0])(params)
    for blk in grads["blocks"]:
        gq = np.asarray(blk["core"]["wq"])           # (R, d, hq_eff, hd)
        assert np.abs(gq[..., cfg.n_heads:, :]).max() == 0.0
        go = np.asarray(blk["core"]["wo"])           # (R, hq_eff, hd, d)
        assert np.abs(go[:, cfg.n_heads:]).max() == 0.0
    ge = np.asarray(grads["embed"])
    assert np.abs(ge[cfg.vocab_size:]).max() == 0.0


def test_padded_train_loss_finite_and_decreasing(rng, key):
    from repro.optim import adamw
    cfg = _padded_cfg()
    params = M.init_params(key, cfg)
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32),
    }

    @jax.jit
    def step(params, opt):
        (loss, _), g = jax.value_and_grad(M.loss_fn, has_aux=True)(params, cfg, batch)
        params, opt, _ = adamw.update(ocfg, g, opt, params)
        return params, opt, loss

    losses = []
    for _ in range(3):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]


# ------------------------------------------------ dynamic batching queue -----
# The serving tier's pow2 padding (launch/serve.py DynamicBatcher) obeys the
# same invariant as the head/vocab pads above: pad rows are dead weight.
# Queue-padded search/classify results must be bit-identical to unpadded
# single-request calls for every ragged size, and pads never leak into
# results or the queue's truncation stats.

from repro import api  # noqa: E402
from repro.core.grid import GridConfig, build_index  # noqa: E402
from repro.core.projection import identity_projection  # noqa: E402
from repro.launch.serve import DynamicBatcher, _pow2  # noqa: E402

QCFG = GridConfig(grid_size=64, tile=8, n_classes=3, window=16, row_cap=8,
                  r0=4, k_slack=2.0)


def _searcher(rng, n=512):
    pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 3, size=n), jnp.int32)
    return api.ActiveSearcher.from_index(
        build_index(pts, QCFG, identity_projection(pts), labels=labels), QCFG
    )


def test_queue_padded_search_bit_identical_ragged_sizes(rng):
    """Every ragged request size 1..B round-trips the queue bit-identically
    to a direct unpadded search — ids, dists, AND the truncated/Eq.-1 stat
    fields, each sliced to exactly the submitted rows."""
    s = _searcher(rng)
    for n in range(1, 10):  # crosses the 1/2/4/8/16 pow2 boundaries
        queries = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
        q = DynamicBatcher(s, k=5)
        fut = q.submit(queries)
        q.drain()
        got, want = fut.result(timeout=0), s.search(queries, 5)
        for f in api.SearchResult._fields:
            a = np.asarray(getattr(got, f))
            assert a.shape[0] == n, f"{f}: pad leaked into shape {a.shape}"
            np.testing.assert_array_equal(
                a, np.asarray(getattr(want, f)), err_msg=f"n={n}:{f}")
        assert q.stats["pad_rows"] == _pow2(n) - n


def test_queue_padded_classify_bit_identical_ragged_sizes(rng):
    s = _searcher(rng)
    for n in (1, 3, 5, 8):
        queries = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
        q = DynamicBatcher(s, k=5)
        fut = q.submit(queries, op="classify")
        q.drain()
        got = np.asarray(fut.result(timeout=0))
        assert got.shape == (n,)
        np.testing.assert_array_equal(
            got, np.asarray(s.classify(queries, 5)), err_msg=f"n={n}")


def test_queue_coalesces_and_slices_per_request(rng):
    """Several ragged requests coalesce into ONE padded batch; each future
    resolves to exactly its own rows."""
    s = _searcher(rng)
    q = DynamicBatcher(s, k=5, max_batch=64)
    sizes = (1, 3, 5, 2)
    queries = [jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
               for n in sizes]
    futs = [q.submit(x) for x in queries]
    q.drain()
    assert q.stats["batches"] == 1
    assert q.stats["pad_rows"] == _pow2(sum(sizes)) - sum(sizes)
    for x, fut in zip(queries, futs):
        got, want = fut.result(timeout=0), s.search(x, 5)
        for f in api.SearchResult._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
                err_msg=f)


def test_queue_pads_never_inflate_truncation_stats(rng):
    """The queue's truncated_rows counter matches the direct search's count
    over the REAL rows — replicated pad rows (which truncate whenever the
    last real row does) are excluded."""
    rng2 = np.random.default_rng(7)
    # clustered points overflow row_cap=8 buckets -> real truncation
    pts = jnp.asarray(rng2.normal(size=(512, 2)) * 0.05, jnp.float32)
    s = api.ActiveSearcher.from_index(
        build_index(pts, QCFG, identity_projection(pts)), QCFG)
    queries = jnp.asarray(rng2.normal(size=(5, 2)) * 0.05, jnp.float32)
    direct = int(np.asarray(s.search(queries, 5).truncated).sum())
    assert direct > 0, "fixture should truncate"
    q = DynamicBatcher(s, k=5)
    q.submit(queries)
    q.drain()
    assert q.stats["pad_rows"] == 3
    assert q.stats["truncated_rows"] == direct


def test_queue_inserts_drain_between_search_batches(rng):
    """A queued insert is invisible to the search batch already in flight
    and visible to the next one — the backlog drains on the batch boundary
    with the counters tracking it."""
    s = _searcher(rng)
    queries = jnp.asarray(rng.normal(size=(4, 2)), jnp.float32)
    new_pts = jnp.asarray(rng.normal(size=(32, 2)), jnp.float32)
    new_labels = jnp.asarray(rng.integers(0, 3, size=32), jnp.int32)

    q = DynamicBatcher(s, k=5)
    f1 = q.submit(queries)
    assert q.offer_insert(new_pts, labels=new_labels) == 32
    assert q.stats["insert_backlog"] == 32
    assert q.step()  # serves the search batch FIRST (insert still queued)
    assert q.stats["insert_backlog"] == 32
    f2_before = s.search(queries, 5)
    for f in api.SearchResult._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(f1.result(timeout=0), f)),
            np.asarray(getattr(f2_before, f)), err_msg=f"pre-insert:{f}")

    assert q.step()  # drains the backlog between batches
    assert q.stats["insert_backlog"] == 0
    assert q.stats["inserts_applied"] == 32
    assert q.stats["insert_backlog_peak"] == 32

    f2 = q.submit(queries)
    q.drain()
    grown = s.insert(new_pts, labels=new_labels)
    for f in api.SearchResult._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(f2.result(timeout=0), f)),
            np.asarray(getattr(grown.search(queries, 5), f)),
            err_msg=f"post-insert:{f}")
