"""Batched serving engine with the paper's technique as a first-class feature:
a kNN-LM head whose datastore is searched with ACTIVE SEARCH (core/knn_lm).

Flow per batch of requests:
  prefill(prompts) -> caches + last hidden
  loop: decode_step -> hidden h_t
        active-search h_t in the datastore -> p_knn   (cost independent of N)
        logits' = log( lam * p_knn + (1-lam) * p_lm )
        sample/argmax -> next token

The datastore maps hidden states -> observed next tokens (Khandelwal-style);
build_datastore_from_model() harvests it from the model's own prefill pass
over a corpus.  Engine throughput/latency stats feed benchmarks/.
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import ARCH_NAMES, get_smoke
from repro.core import knn_lm
from repro.core.grid import GridIndex
from repro.launch.mesh import make_host_mesh
from repro.launch import steps as st
from repro.models import model as M


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    greedy: bool = True
    temperature: float = 1.0
    knn: knn_lm.KNNLMConfig | None = None
    seed: int = 0


def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


class DynamicBatcher:
    """Async request queue with dynamic batching over one `ActiveSearcher`.

    Requests (`submit`) are coalesced into batches padded up to the next
    power of two — the SAME pow2 ladder the jitted cores already compile
    for (core/mutable.py pads insert batches identically), so a ragged
    request stream hits a handful of cached executables instead of one
    retrace per batch size.  Pad rows replicate the last real query and are
    sliced off before a request's future resolves: results are bit-identical
    to an unpadded call (tests/test_padding.py) and pads never leak into the
    queue's truncation stats.

    `offer_insert` queues `--knn-online` datastore growth instead of
    applying it inline; the backlog drains BETWEEN search batches (`step`
    alternates: one search batch, then any queued inserts), so a decode
    stream never waits on an insert mid-batch, and compaction pauses land
    on the batch boundary.  `stats` tracks the backlog depth, pad overhead,
    per-request latency, and the searcher's own compaction accounting.
    """

    def __init__(self, searcher, k: int, max_batch: int = 64):
        if max_batch <= 0:
            raise ValueError(f"max_batch must be positive, got {max_batch}")
        self.searcher = searcher
        self.k = k
        self.max_batch = max_batch
        self._requests: collections.deque = collections.deque()
        self._inserts: collections.deque = collections.deque()
        self._after_search = False  # drain inserts before the next batch
        self.stats = {
            "requests": 0, "request_rows": 0, "batches": 0, "batch_rows": 0,
            "pad_rows": 0, "truncated_rows": 0, "insert_rows_queued": 0,
            "insert_backlog": 0, "insert_backlog_peak": 0,
            "inserts_applied": 0, "latencies_s": [],
        }

    # ------------------------------------------------------------- enqueue --
    def submit(self, queries, op: str = "search") -> Future:
        """Queue a (Q, d) request; the future resolves to a `SearchResult`
        (op="search") or (Q,) predictions (op="classify") for exactly the
        submitted rows."""
        if op not in ("search", "classify"):
            raise ValueError(f"op must be 'search' or 'classify', got {op!r}")
        q = np.asarray(queries)
        if q.ndim != 2 or q.shape[0] == 0:
            raise ValueError(f"queries must be (Q>0, d), got {q.shape}")
        fut: Future = Future()
        self._requests.append((op, q, fut, time.perf_counter()))
        self.stats["requests"] += 1
        self.stats["request_rows"] += q.shape[0]
        return fut

    def offer_insert(self, points, labels=None, ids=None) -> int:
        """Queue datastore growth; applied between search batches (or by
        `drain`).  Returns the current insert backlog depth in rows."""
        self._inserts.append((points, labels, ids))
        self.stats["insert_rows_queued"] += int(points.shape[0])
        backlog = sum(int(p.shape[0]) for p, _, _ in self._inserts)
        self.stats["insert_backlog"] = backlog
        self.stats["insert_backlog_peak"] = max(
            self.stats["insert_backlog_peak"], backlog
        )
        return backlog

    # -------------------------------------------------------------- serve ---
    def step(self) -> bool:
        """Run ONE unit of work: the insert backlog if a search batch just
        ran (or nothing else is queued), else one dynamic search batch.
        Returns False when both queues are empty."""
        if self._inserts and (self._after_search or not self._requests):
            self._apply_inserts()
            self._after_search = False
            return True
        if not self._requests:
            return False
        self._run_batch()
        self._after_search = True
        return True

    def drain(self) -> None:
        """Serve until both the request and insert queues are empty."""
        while self.step():
            pass

    async def run_async(self, poll_s: float = 0.001) -> None:
        """Cooperative serving loop for an asyncio host: steps whenever work
        is queued, yields to the event loop when idle.  Cancel to stop."""
        import asyncio

        while True:
            if not self.step():
                await asyncio.sleep(poll_s)

    # ------------------------------------------------------------ internals -
    def _apply_inserts(self) -> None:
        rows = 0
        while self._inserts:
            pts, labels, ids = self._inserts.popleft()
            self.searcher = self.searcher.insert(pts, labels=labels, ids=ids)
            rows += int(pts.shape[0])
        self.stats["inserts_applied"] += rows
        self.stats["insert_backlog"] = 0

    def _run_batch(self) -> None:
        op = self._requests[0][0]
        batch, rows = [], 0
        while (self._requests and self._requests[0][0] == op
               and rows < self.max_batch):
            batch.append(self._requests.popleft())
            rows += batch[-1][1].shape[0]
        qs = np.concatenate([b[1] for b in batch], axis=0)
        n = qs.shape[0]
        pad = _pow2(n) - n
        if pad:
            qs = np.concatenate([qs, np.repeat(qs[-1:], pad, axis=0)], axis=0)
        qj = jnp.asarray(qs, jnp.float32)
        if op == "search":
            out = self.searcher.search(qj, self.k)
            self.stats["truncated_rows"] += int(
                np.asarray(out.truncated[:n]).sum()
            )
        else:
            out = self.searcher.classify(qj, self.k)
        t_done = time.perf_counter()
        ofs = 0
        for _, q, fut, t0 in batch:
            m = q.shape[0]
            if op == "search":
                fut.set_result(jax.tree.map(lambda a: a[ofs:ofs + m], out))
            else:
                fut.set_result(out[ofs:ofs + m])
            ofs += m
            self.stats["latencies_s"].append(t_done - t0)
        self.stats["batches"] += 1
        self.stats["batch_rows"] += n
        self.stats["pad_rows"] += pad


class Engine:
    """Batched generation over a fixed mesh; caches donated step to step."""

    def __init__(self, cfg, params, mesh, sc: ServeConfig,
                 datastore: GridIndex | None = None):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.sc = sc
        self.datastore = datastore
        self._serve_step, _, self._params_sh, self._jit_for = st.make_serve_step(
            cfg, mesh
        )
        self._compiled = {}
        # --knn-online growth queue: opened on first use and kept across
        # batches, so chained inserts reuse the searcher's slack state (free
        # bucket slots) instead of re-deriving the layout every time
        self._ds_queue: DynamicBatcher | None = None
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "tokens": 0}

    def _decode_fn(self, caches, token, pos):
        key = tuple(jax.tree.leaves(jax.tree.map(lambda a: a.shape, caches))[0:1])
        if key not in self._compiled:
            dec_abs = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                {"caches": caches, "token": token, "pos": pos},
            )
            with self.mesh:
                self._compiled[key] = self._jit_for(dec_abs)
        return self._compiled[key]

    def generate(self, prompts: np.ndarray, max_new: int | None = None):
        """prompts: (B, S) int32.  Returns (tokens (B, new), hiddens) where
        hiddens is a LIST of new-1 per-step (B, d) arrays — hiddens[j] is the
        state that predicted tokens[:, j+1] (the prefill hidden that produced
        tokens[:, 0] is not collected), the pairing extend_datastore relies
        on."""
        sc = self.sc
        max_new = max_new or sc.max_new_tokens
        b, s = prompts.shape
        cache_len = s + max_new

        t0 = time.time()
        with self.mesh:
            logits, caches, hidden = jax.jit(
                lambda p, batch: M.prefill(p, self.cfg, batch, cache_len=cache_len)
            )(self.params, {"tokens": jnp.asarray(prompts, jnp.int32)})
        jax.block_until_ready(logits)
        self.stats["prefill_s"] += time.time() - t0

        key = jax.random.PRNGKey(sc.seed)
        out_tokens, out_hidden = [], []
        tok = self._pick(logits, hidden, key, 0)
        out_tokens.append(tok)
        t1 = time.time()
        for i in range(max_new - 1):
            pos = jnp.int32(s + i)
            fn = self._decode_fn(caches, tok, pos)
            with self.mesh:
                logits, caches, hidden = fn(self.params, caches, tok, pos)
            key, sub = jax.random.split(key)
            tok = self._pick(logits, hidden, sub, i + 1)
            out_tokens.append(tok)
            out_hidden.append(hidden)
        jax.block_until_ready(tok)
        self.stats["decode_s"] += time.time() - t1
        self.stats["tokens"] += b * max_new
        toks = jnp.stack(out_tokens, axis=1)
        return np.asarray(toks), out_hidden

    def datastore_queue(self) -> DynamicBatcher:
        """The engine's dynamic-batching queue over the kNN-LM datastore,
        opened on first use.  Its searcher owns the datastore's slack state
        across batches; `drain_datastore` republishes the grown snapshot."""
        if self.datastore is None or self.sc.knn is None:
            raise ValueError("datastore_queue needs a kNN-LM datastore")
        if self._ds_queue is None:
            searcher = api.ActiveSearcher.from_index(
                self.datastore, self.sc.knn.grid, plan=self.sc.knn.plan
            )
            self._ds_queue = DynamicBatcher(searcher, k=self.sc.knn.k)
        return self._ds_queue

    def queue_datastore_pairs(self, hiddens, tokens) -> int:
        """Queue ONLINE datastore growth from this engine's own decode
        stream: `hiddens` is the per-step hidden list from `generate`,
        `tokens` the (B, new) emitted tokens.  Pairs (h_t -> token_{t+1})
        enter the insert backlog (applied between search batches — see
        DynamicBatcher); returns the number of pairs queued."""
        if not hiddens:
            return 0
        keys = jnp.concatenate(
            [h.astype(jnp.float32) for h in hiddens], axis=0
        )  # (B*(new-1), d)
        vals = jnp.asarray(tokens[:, 1:], jnp.int32).T.reshape(-1)
        self.datastore_queue().offer_insert(keys, labels=vals)
        return int(keys.shape[0])

    def drain_datastore(self) -> int:
        """Apply the queued inserts (core/mutable.py deltas — no rebuild,
        no PCA re-fit) and publish the grown datastore so the next
        `generate` call searches it.  Returns the rows applied."""
        if self._ds_queue is None:
            return 0
        before = self._ds_queue.stats["inserts_applied"]
        self._ds_queue.drain()
        self.datastore = self._ds_queue.searcher.index
        return self._ds_queue.stats["inserts_applied"] - before

    def extend_datastore(self, hiddens, tokens) -> int:
        """Synchronous grow: queue the decode stream's pairs and drain at
        once.  Returns the number of pairs added."""
        if self.datastore is None or self.sc.knn is None:
            raise ValueError("extend_datastore needs a kNN-LM datastore")
        added = self.queue_datastore_pairs(hiddens, tokens)
        self.drain_datastore()
        return added

    def _pick(self, lm_logits, hidden, key, step):
        if self.datastore is not None and self.sc.knn is not None:
            logp = knn_lm.knn_lm_logits(
                self.datastore, self.sc.knn, hidden.astype(jnp.float32), lm_logits
            )
        else:
            logp = jax.nn.log_softmax(lm_logits, axis=-1)
        if self.sc.greedy:
            return jnp.argmax(logp, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logp / self.sc.temperature, axis=-1
        ).astype(jnp.int32)


def build_datastore_from_model(cfg, params, corpus: np.ndarray, knn_cfg) -> GridIndex:
    """Harvest (hidden_t -> token_{t+1}) pairs from a prefill pass over
    `corpus` (B, S) and build the active-search datastore."""
    @jax.jit
    def hiddens(batch):
        x = M.embed_inputs(params, cfg, batch)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)

        def body(x, block_slice):
            for p in range(cfg.block_period):
                x, _ = M._apply_layer_train(block_slice[p], cfg, p, x, positions)
            return x, None

        if cfg.policy.scan_layers and cfg.n_repeat > 1:
            x, _ = jax.lax.scan(body, x, params["blocks"])
        else:
            for r in range(cfg.n_repeat):
                blk = [jax.tree.map(lambda a: a[r], params["blocks"][p])
                       for p in range(cfg.block_period)]
                x, _ = body(x, blk)
        import repro.models.layers as L
        return L.rms_norm(x, params["final_norm"], cfg.norm_eps)

    h = hiddens({"tokens": jnp.asarray(corpus, jnp.int32)})      # (B, S, d)
    keys = np.asarray(h[:, :-1, :], np.float32).reshape(-1, h.shape[-1])
    vals = corpus[:, 1:].reshape(-1).astype(np.int32)
    return knn_lm.build_datastore(jnp.asarray(keys), jnp.asarray(vals), knn_cfg)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES, default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--knn", action="store_true", help="enable the kNN-LM head")
    ap.add_argument("--datastore-size", type=int, default=8192)
    ap.add_argument(
        "--knn-backend", default="jnp",
        help="registered active-search backend for the datastore "
             "(repro.api.registered_backends(); 'pallas' = batched kernels, "
             "interpret-mode on CPU, Mosaic with REPRO_PALLAS_INTERPRET=0)",
    )
    ap.add_argument(
        "--knn-chunk", type=int, default=None,
        help="stream datastore searches through fixed-size query chunks "
             "(bounds kernel VMEM at serve scale; results are identical)",
    )
    ap.add_argument(
        "--knn-online", action="store_true",
        help="grow the kNN-LM datastore DURING serving: after each batch, "
             "delta-insert the decoded (hidden, next-token) pairs "
             "(core/mutable.py) so later batches retrieve from them — no "
             "rebuild between batches",
    )
    args = ap.parse_args()
    if args.knn_online and not args.knn:
        raise SystemExit("--knn-online requires --knn")
    if args.knn:
        # fail on a bad backend name NOW, not after model init + datastore
        # build; count-only backends can't serve searches either
        try:
            impl = api.get_backend(args.knn_backend)
        except ValueError as e:
            raise SystemExit(f"--knn-backend: {e}") from None
        if impl.search is None or impl.requires_mesh:
            # mesh-requiring backends (sharded) implement search() but only
            # on a build_sharded handle; the datastore handle here is
            # from_index-built, so it would fail after model init
            searchable = [n for n in api.registered_backends()
                          if api.get_backend(n).search is not None
                          and not api.get_backend(n).requires_mesh]
            raise SystemExit(
                f"--knn-backend {args.knn_backend!r} cannot serve datastore "
                f"searches; pick one of {searchable}"
            )
        if args.knn_online and not impl.supports_mutation:
            # capability-driven, not name-matched: online growth needs a
            # backend that can serve the refreshed post-insert snapshot
            mutable = [n for n in api.registered_backends()
                       if api.get_backend(n).supports_mutation
                       and not api.get_backend(n).requires_mesh]
            raise SystemExit(
                f"--knn-online: backend {args.knn_backend!r} does not "
                f"support mutation (BackendImpl.supports_mutation); pick "
                f"one of {mutable}"
            )

    cfg = get_smoke(args.arch)
    mesh = make_host_mesh(1, 1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    # ONE ExecutionPlan carries every execution knob from the CLI down
    # through KNNLMConfig -> ActiveSearcher; no per-signature re-plumbing
    plan = api.ExecutionPlan(backend=args.knn_backend, chunk_size=args.knn_chunk)
    knn_cfg = knn_lm.KNNLMConfig(plan=plan) if args.knn else None
    datastore = None
    if args.knn:
        corpus = rng.integers(
            0, cfg.vocab_size, size=(args.datastore_size // 64, 65), dtype=np.int32
        )
        datastore = build_datastore_from_model(cfg, params, corpus, knn_cfg)
        print(f"[serve] datastore: {datastore.n_points} keys "
              f"(search backend: {args.knn_backend})")

    engine = Engine(cfg, params, mesh, ServeConfig(knn=knn_cfg), datastore)
    prompts = rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len),
                           dtype=np.int32)
    toks, hiddens = engine.generate(prompts, args.max_new)
    if args.knn_online:
        added = engine.queue_datastore_pairs(hiddens, toks)
        q = engine.datastore_queue()
        print(f"[serve] insert backlog: {q.stats['insert_backlog']} rows "
              f"(peak {q.stats['insert_backlog_peak']})")
        engine.drain_datastore()
        print(f"[serve] datastore grew online: +{added} pairs -> "
              f"{engine.datastore.n_points} keys (no rebuild)")
        prompts2 = rng.integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len), dtype=np.int32
        )
        toks, _ = engine.generate(prompts2, args.max_new)
    s = engine.stats
    print(f"[serve] generated {toks.shape} tokens")
    print(
        f"[serve] prefill {s['prefill_s']*1e3:.1f} ms, "
        f"decode {s['decode_s']*1e3:.1f} ms "
        f"({s['tokens']/max(s['decode_s'],1e-9):.1f} tok/s)"
    )


if __name__ == "__main__":
    main()
