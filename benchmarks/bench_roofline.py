"""Roofline table reader: renders §Roofline of EXPERIMENTS.md from the
dry-run artifact (runs/dryrun_single.jsonl).  No compilation here — run
`python -m repro.launch.dryrun --all --mesh single --out runs/dryrun_single.jsonl`
first (hours of XLA compiles)."""

from __future__ import annotations

import json
import os

from benchmarks.common import Csv

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "runs", "dryrun_single_v3.jsonl")


def load(path: str = DEFAULT) -> list[dict]:
    if not os.path.exists(path):
        return []
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r.get("mesh"))] = r  # last wins
    return list(recs.values())


def main(path: str = DEFAULT) -> None:
    recs = load(path)
    csv = Csv("arch,shape,status,compute_s,memory_s,collective_s,bottleneck,"
              "model_flops_ratio,temp_gib,mem_upper_s")
    if not recs:
        csv.row("(no dry-run artifact found — run repro.launch.dryrun first)",
                "", "", "", "", "", "", "", "")
        return csv
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "OK":
            csv.row(r["arch"], r["shape"], r.get("status", "?"),
                    "", "", "", "", "", "", "")
            continue
        roof = r["roofline"]
        mem = (r.get("memory") or {}).get("temp_size_in_bytes", 0) / 2**30
        csv.row(
            r["arch"], r["shape"], "OK",
            f"{roof['compute_s']:.3f}", f"{roof['memory_s']:.3f}",
            f"{roof['collective_s']:.3f}", roof["bottleneck"],
            f"{(r.get('model_flops_ratio') or 0):.3f}", f"{mem:.2f}",
            f"{roof.get('memory_upper_s', roof['memory_s']):.3f}",
        )
    return csv


if __name__ == "__main__":
    main()
