"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real device
count (1 CPU).  Multi-device tests spawn subprocesses (test_distributed.py).
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
