"""Hypothesis shim: use the real library when installed, else a minimal
deterministic fallback so the suite still collects and runs.

The fallback reimplements exactly the subset this repo's tests use:

  @settings(max_examples=N, deadline=None)
  @given(seed=hst.integers(0, 2**31 - 1), k=hst.integers(1, 20), ...)

Draws are deterministic (seeded per example index), so failures reproduce.
Real hypothesis, when present, wins — shrinking and the full strategy
language come back for free.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def draw(self, rnd: random.Random):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value=None, max_value=None):
            self.min_value = -(2**31) if min_value is None else min_value
            self.max_value = 2**31 - 1 if max_value is None else max_value

        def draw(self, rnd):
            return rnd.randint(self.min_value, self.max_value)

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=10):
            self.elements = elements
            self.min_size = min_size
            self.max_size = max_size

        def draw(self, rnd):
            size = rnd.randint(self.min_size, self.max_size)
            return [self.elements.draw(rnd) for _ in range(size)]

    class _Booleans(_Strategy):
        def draw(self, rnd):
            return bool(rnd.randint(0, 1))

    class _Floats(_Strategy):
        def __init__(self, min_value=0.0, max_value=1.0, **_ignored):
            self.min_value = min_value
            self.max_value = max_value

        def draw(self, rnd):
            return rnd.uniform(self.min_value, self.max_value)

    class _SampledFrom(_Strategy):
        def __init__(self, elements):
            self.elements = list(elements)

        def draw(self, rnd):
            return rnd.choice(self.elements)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value=None, max_value=None):
            return _Integers(min_value, max_value)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Lists(elements, min_size=min_size, max_size=max_size)

        @staticmethod
        def booleans():
            return _Booleans()

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **kwargs):
            return _Floats(min_value, max_value, **kwargs)

        @staticmethod
        def sampled_from(elements):
            return _SampledFrom(elements)

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES)
                for i in range(n):
                    rnd = random.Random(0xA5EED + i)
                    drawn = {
                        name: strat.draw(rnd)
                        for name, strat in strategy_kwargs.items()
                    }
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:  # attach the failing example
                        raise AssertionError(
                            f"falsifying example (compat shim, example {i}): {drawn}"
                        ) from e

            # hide the drawn parameters from pytest's fixture resolution:
            # only NON-strategy params (real fixtures like `rng`) remain.
            sig = inspect.signature(fn)
            params = [
                p for name, p in sig.parameters.items()
                if name not in strategy_kwargs
            ]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper

        return deco
