"""AdamW from scratch (no optax in this environment): fp32 moments, global-norm
clip, cosine schedule with warmup, decoupled weight decay.

Optimizer state is a pytree with the same structure/sharding as the params,
so sharded (FSDP) params give ZeRO-1-sharded optimizer state for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros), count=jnp.int32(0))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _decay_mask(path: tuple) -> bool:
    """Decay matrices only — not norms/biases/gates (standard practice)."""
    name = getattr(path[-1], "key", None)
    return name not in (
        "norm1", "norm2", "final_norm", "bias", "conv_b",
        "dt_bias", "fgate_bias", "A_log", "D",
    )


def update(
    cfg: AdamWConfig, grads: Any, state: OptState, params: Any
) -> tuple[Any, OptState, dict]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    c = count.astype(jnp.float32)
    mu_hat_scale = 1.0 / (1.0 - b1**c)
    nu_hat_scale = 1.0 / (1.0 - b2**c)

    def step(path, p, m, v):
        upd = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)
        if _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    new_params = jax.tree_util.tree_map_with_path(step, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(mu, nu, count), metrics
