"""Pallas TPU kernel: fused CSR-gather -> distance -> streaming top-k.

`candidate_topk` ranks candidates that a separate gather stage already
materialized as a dense (B, w*row_cap, d) tensor in HBM — four full-field
`jnp.take`s (points/coords/labels/ids) whose rows are mostly padding
(`valid` masks the slack).  This kernel retires that intermediate: each
query-program reads its window spans from scalar-prefetched SMEM and DMAs
candidate rows DIRECTLY from the CSR-sorted store (which never leaves HBM)
into a double-buffered VMEM scratch, so the only thing the candidate stage
ever writes back is the (B, k) result.

Per grid program (one query):

  1. warm-up DMA of window row 0 (`row_cap` store rows starting at the
     clamped span start) into buffer slot 0;
  2. for each of the `w` window rows: kick off the NEXT row's DMA into the
     other slot, wait on the current slot, compute the metric distance of
     its `row_cap` rows against the query on the VPU, and write
     (masked distance, global CSR row index) into a (1, w*row_cap) VMEM
     accumulator pair — invalid lanes (outside [start, end), past the live
     CSR length, or outside the paper-mode circle) get +inf;
  3. run the streaming (min, argmin, mask) top-k over the accumulator —
     k is small (<=64) so the unrolled select beats a sort — emitting
     distances and GLOBAL CSR indices, so record assembly downstream is one
     (B, k) take per field instead of four (B, w*row_cap) gathers.

Masking/tie-break contract is IDENTICAL to gather_candidates_batched +
candidate_topk lane for lane (same candidate order, same clamped span
starts, first-index argmin ties), so the fused path is bit-for-bit with the
gather path and with the per-query jnp reference.  `center_cells=True` +
`radii` reproduce mode="paper" (rank floor(coords)+0.5 cell centers,
mask to the final Eq.-1 circle).  Validated with interpret=True against
ref.csr_candidate_topk.

VMEM per program: 2 * row_cap * d floats of row buffer + 2 * w * row_cap
accumulator lanes — independent of B and of N, which is what lets
serve-scale batches stream through fixed-size invocations while the store
scales to millions of points.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    span_ref,   # scalar prefetch: (B, 2w) int32 — [starts | ends] CSR spans
    rad_ref,    # scalar prefetch: (B,) float32 — Eq.-1 radii (paper mode)
    q_ref,      # (1, d) float32 — this query's ranking vector
    store_ref,  # (n_pad, d) float32 — CSR-sorted store, stays in HBM/ANY
    outd_ref,   # (1, k) float32
    outi_ref,   # (1, k) int32 — global CSR row indices (-1 where invalid)
    buf_ref,    # scratch (2, row_cap, d) float32 — double-buffered rows
    dist_ref,   # scratch (1, w*row_cap) float32
    gidx_ref,   # scratch (1, w*row_cap) int32
    sem,        # DMA semaphores (2,)
    *,
    w: int,
    row_cap: int,
    k: int,
    n: int,
    n_pad: int,
    d_chunks: tuple[tuple[int, int], ...],
    metric: str,
    center_cells: bool,
    use_radius: bool,
):
    i = pl.program_id(0)
    q = q_ref[...]                            # (1, d)
    r = rad_ref[i]
    s_max = max(n_pad - row_cap, 0)

    def s_cl(row):
        # same clamp as the gather path: a span start near the end of the
        # store still yields an in-bounds row_cap slice
        return jnp.clip(span_ref[i, row], 0, s_max)

    def row_dma(slot, row):
        return pltpu.make_async_copy(
            store_ref.at[pl.ds(s_cl(row), row_cap)],
            buf_ref.at[slot],
            sem.at[slot],
        )

    row_dma(0, 0).start()

    def body(row, carry):
        slot = jax.lax.rem(row, 2)

        @pl.when(row + 1 < w)
        def _prefetch_next():
            row_dma(jax.lax.rem(row + 1, 2), row + 1).start()

        row_dma(slot, row).wait()
        rows = buf_ref[slot]                  # (row_cap, d)
        if center_cells:                      # paper mode ranks cell centers
            rows = jnp.floor(rows) + 0.5
        diff = rows - q                       # broadcast over row_cap
        if metric == "l1":
            acc = sum(
                jnp.sum(jnp.abs(diff[:, c0:c0 + dc]), axis=1)
                for c0, dc in d_chunks
            )
            dist = acc
        else:
            acc = sum(
                jnp.sum(diff[:, c0:c0 + dc] * diff[:, c0:c0 + dc], axis=1)
                for c0, dc in d_chunks
            )
            dist = jnp.sqrt(jnp.maximum(acc, 0.0))
        j = s_cl(row) + jax.lax.broadcasted_iota(jnp.int32, (row_cap,), 0)
        ok = (j >= span_ref[i, row]) & (j < span_ref[i, w + row]) & (j < n)
        if use_radius:
            ok = ok & (dist <= r)
        dist_ref[0, pl.ds(row * row_cap, row_cap)] = jnp.where(
            ok, dist, jnp.inf
        )
        gidx_ref[0, pl.ds(row * row_cap, row_cap)] = j
        return carry

    jax.lax.fori_loop(0, w, body, 0)

    dcur = dist_ref[...]                      # (1, w*row_cap)
    col = jax.lax.broadcasted_iota(jnp.int32, dcur.shape, 1)
    dists, idxs = [], []
    for _ in range(k):
        m = jnp.min(dcur, axis=1)             # (1,)
        am = jnp.argmin(dcur, axis=1)         # (1,) first-index ties
        dists.append(m[0])
        g = gidx_ref[0, am[0]]
        idxs.append(jnp.where(jnp.isfinite(m[0]), g, -1))
        dcur = jnp.where(col == am[:, None], jnp.inf, dcur)
    outd_ref[0, :] = jnp.stack(dists)
    outi_ref[0, :] = jnp.stack(idxs)


@functools.partial(
    jax.jit,
    static_argnames=(
        "k", "n", "row_cap", "metric", "center_cells", "d_chunk", "interpret"
    ),
)
def csr_candidate_topk(
    store: jax.Array,    # (n_pad, d) float32 — CSR-sorted ranking vectors
    starts: jax.Array,   # (B, w) int32 — window-row span starts
    ends: jax.Array,     # (B, w) int32 — window-row span ends
    queries: jax.Array,  # (B, d) float32 — per-query ranking vectors
    k: int,
    n: int,              # live CSR rows (store rows >= n are padding)
    row_cap: int,
    metric: str = "l2",
    radii: jax.Array | None = None,  # (B,) float32 — paper-mode circle mask
    center_cells: bool = False,      # rank floor(store)+0.5 cell centers
    d_chunk: int | None = None,      # split the d-accumulation (None = one sum)
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Contract identical to ref.csr_candidate_topk.

    Returns (dists (B, k) float32 with +inf pads, idx (B, k) int32 GLOBAL
    CSR row indices with -1 pads).  `n_pad = store.shape[0]` must be
    >= row_cap (pad the store first — see active_search.padded_csr).
    """
    n_pad, d = store.shape
    b, w = starts.shape
    if n_pad < row_cap:
        raise ValueError(
            f"store has {n_pad} rows but row_cap={row_cap}; pad the store "
            f"(active_search.padded_csr) so every span slice is in bounds"
        )
    if ends.shape != (b, w):
        raise ValueError(f"ends shape {ends.shape} != starts {starts.shape}")
    if queries.shape != (b, d):
        # the grid is sized from the spans; a short queries array would have
        # its block index clamped and silently rank trailing spans against a
        # repeated query instead of failing
        raise ValueError(
            f"queries shape {queries.shape} does not match spans batch "
            f"{b} x store dim {d}"
        )
    if radii is not None and radii.shape != (b,):
        raise ValueError(
            f"radii shape {radii.shape} does not match spans batch ({b},)"
        )
    dc = d if d_chunk is None else max(1, min(d_chunk, d))
    d_chunks = tuple((c0, min(dc, d - c0)) for c0 in range(0, d, dc))

    spans = jnp.concatenate([starts, ends], axis=1).astype(jnp.int32)
    rad = (
        jnp.zeros((b,), jnp.float32) if radii is None
        else radii.astype(jnp.float32)
    )
    kernel = functools.partial(
        _kernel,
        w=w, row_cap=row_cap, k=k, n=n, n_pad=n_pad, d_chunks=d_chunks,
        metric=metric, center_cells=center_cells,
        use_radius=radii is not None,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, *_: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # store: manual DMA only
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, *_: (i, 0)),
            pl.BlockSpec((1, k), lambda i, *_: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, row_cap, d), jnp.float32),
            pltpu.VMEM((1, w * row_cap), jnp.float32),
            pltpu.VMEM((1, w * row_cap), jnp.int32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(spans, rad, queries.astype(jnp.float32), store.astype(jnp.float32))
