"""Paper §3: 'When the L1 distance is taken, the computational cost could be
extremely cheap, while the result would be more roughly approximated'."""

from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Csv, paper_data, timeit
from repro.api import ActiveSearcher, GridConfig, identity_projection
from repro.core import exact

K, N = 11, 20_000


def main() -> None:
    rng = np.random.default_rng(0)
    pts, labels = paper_data(rng, N)
    q, _ = paper_data(rng, 100)
    truth = exact.classify(q, pts, labels, K, 3)  # L2 ground truth
    csv = Csv("metric_or_counter,accuracy_vs_l2_exact,query_s")
    variants = [
        ("l2", {"metric": "l2"}),
        ("l1", {"metric": "l1"}),
        # beyond-paper: exact L-inf counts via summed-area table (4 gathers,
        # any radius — integral.py)
        ("sat_linf", {"metric": "l2", "counter": "sat"}),
    ]
    for name, kw in variants:
        cfg = GridConfig(grid_size=512, tile=16, n_classes=3, window=64,
                         row_cap=64, r0=16, k_slack=2.0, **kw)
        searcher = ActiveSearcher.build(
            pts, labels=labels, cfg=cfg, proj=identity_projection(pts)
        )
        pred = searcher.classify(q, K)
        acc = float(np.mean(np.asarray(pred) == np.asarray(truth)))
        t = timeit(lambda: searcher.classify(q, K), repeats=3)
        csv.row(name, f"{acc:.3f}", f"{t:.4f}")
    return csv


if __name__ == "__main__":
    main()
