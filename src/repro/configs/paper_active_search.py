"""The paper's own experimental setup (§3): randomly generated 2-D points,
3 classes, k=11, 100 query points, 3000x3000 image, r0=100 pixels."""

from repro.core.grid import GridConfig

K = 11
N_CLASSES = 3
N_QUERIES = 100

PAPER_GRID = GridConfig(
    grid_size=3000,
    tile=16,
    n_classes=N_CLASSES,
    window=128,
    row_cap=64,
    r0=100,
    max_iters=16,
    k_slack=1.0,   # the paper's exact n == k stopping rule
    metric="l2",
)

# production profile: generous acceptance band, smaller initial radius
PROD_GRID = GridConfig(
    grid_size=1024,
    tile=16,
    n_classes=0,
    window=64,
    row_cap=64,
    r0=8,
    max_iters=12,
    k_slack=4.0,
    metric="l2",
)
