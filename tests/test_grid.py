"""GridIndex structural invariants — unit + property (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as hst

from repro.core import grid as G
from repro.core import projection as proj_lib


def _build(points, n_classes=0, grid_size=64, labels=None):
    cfg = G.GridConfig(grid_size=grid_size, tile=8, n_classes=n_classes,
                       window=8, row_cap=16, r0=4)
    proj = proj_lib.identity_projection(points)
    return cfg, G.build_index(points, cfg, proj, labels=labels)


def test_invariants_basic(rng):
    pts = jnp.asarray(rng.normal(size=(500, 2)), jnp.float32)
    cfg, idx = _build(pts)
    inv = G.validate_invariants(idx, cfg)
    assert all(inv.values()), inv


def test_csr_matches_counts(rng):
    pts = jnp.asarray(rng.normal(size=(300, 2)), jnp.float32)
    cfg, idx = _build(pts)
    g = cfg.padded_size
    counts = np.asarray(idx.offsets[1:] - idx.offsets[:-1]).reshape(g, g)
    base = np.asarray(G.base_counts(idx))
    np.testing.assert_array_equal(counts, base)


def test_class_channels(rng):
    pts = jnp.asarray(rng.normal(size=(400, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 3, size=400), jnp.int32)
    cfg, idx = _build(pts, n_classes=3, labels=labels)
    per_class = np.asarray(idx.pyramid[0].sum(axis=(0, 1)))
    expect = np.bincount(np.asarray(labels), minlength=3)
    np.testing.assert_array_equal(per_class, expect)


def test_pyramid_levels_sum(rng):
    pts = jnp.asarray(rng.normal(size=(256, 2)), jnp.float32)
    cfg, idx = _build(pts)
    for lv, arr in enumerate(idx.pyramid):
        assert int(arr.sum()) == 256, f"level {lv} mass"
        assert arr.shape[0] == cfg.padded_size // (1 << lv)


def test_points_sorted_by_cell(rng):
    pts = jnp.asarray(rng.uniform(size=(200, 2)), jnp.float32)
    cfg, idx = _build(pts)
    cid = np.asarray(G.cell_id_of(idx.coords_sorted, cfg.padded_size))
    assert (np.diff(cid) >= 0).all()


def test_ids_are_permutation(rng):
    pts = jnp.asarray(rng.normal(size=(100, 2)), jnp.float32)
    _, idx = _build(pts)
    assert sorted(np.asarray(idx.ids_sorted).tolist()) == list(range(100))


@settings(max_examples=25, deadline=None)
@given(
    n=hst.integers(min_value=1, max_value=200),
    seed=hst.integers(min_value=0, max_value=2**31 - 1),
    d=hst.integers(min_value=2, max_value=5),
)
def test_property_invariants(n, seed, d):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.normal(size=(n, d)) * rng.uniform(0.1, 10), jnp.float32)
    cfg = G.GridConfig(grid_size=32, tile=8, window=8, row_cap=max(16, n), r0=2)
    proj = (proj_lib.identity_projection(pts) if d == 2
            else proj_lib.gaussian_projection(jax.random.PRNGKey(seed), pts))
    idx = G.build_index(pts, cfg, proj)
    inv = G.validate_invariants(idx, cfg)
    assert all(inv.values()), inv


def test_grid_config_levels():
    cfg = G.GridConfig(grid_size=3000, tile=16)
    # padded to tile * 2**(levels-1) >= 3000
    assert cfg.padded_size >= 3000
    assert cfg.padded_size == cfg.tile * (1 << (cfg.levels - 1))
    assert cfg.padded_size // (1 << (cfg.levels - 1)) == cfg.tile


@pytest.mark.parametrize("tile", [0, 1, 2, 3])
def test_grid_config_rejects_degenerate_tile(tile):
    """tile <= 3 breaks level_for_radius's containment guarantee (its
    max(tile - 3, 1) divisor would silently under-select levels)."""
    with pytest.raises(ValueError, match="tile"):
        G.GridConfig(grid_size=64, tile=tile)


def test_grid_config_accepts_min_tile():
    # explicit r0: the default (100) exceeds max_radius on a 64-wide grid
    assert G.GridConfig(grid_size=64, tile=4, r0=8).tile == 4


@pytest.mark.parametrize("r0", [0, -1, -100])
def test_grid_config_rejects_nonpositive_r0(r0):
    """The radius loop used to jnp.clip a bad r0 silently — a typo'd start
    radius ran with a DIFFERENT schedule than configured.  Rejected eagerly
    now, like tile/metric/counter."""
    with pytest.raises(ValueError, match="r0"):
        G.GridConfig(grid_size=64, tile=8, r0=r0)


def test_grid_config_rejects_r0_beyond_max_radius():
    cfg_probe = G.GridConfig(grid_size=64, tile=8, r0=8)
    too_big = cfg_probe.max_radius + 1
    with pytest.raises(ValueError, match="max_radius"):
        G.GridConfig(grid_size=64, tile=8, r0=too_big)
    # the boundary itself is legal: max_radius is countable from the top tile
    assert G.GridConfig(grid_size=64, tile=8,
                        r0=cfg_probe.max_radius).r0 == cfg_probe.max_radius
    assert G.GridConfig(grid_size=64, tile=8, r0=1).r0 == 1


def test_flattened_tiles_layout(rng):
    """pyr_tiles is the level-major T-tiling of the pyramid: tile (bx, by)
    of level l lives at offset_l + bx * nblk_l + by."""
    pts = jnp.asarray(rng.normal(size=(300, 2)), jnp.float32)
    cfg, idx = _build(pts)
    assert idx.pyr_tiles.shape == (
        sum(nb * nb for nb in cfg.level_nblks), cfg.tile, cfg.tile, 1
    )
    off = 0
    for lv, arr in enumerate(idx.pyramid):
        nb = arr.shape[0] // cfg.tile
        assert nb == cfg.level_nblks[lv]
        for bx, by in ((0, 0), (nb - 1, 0), (nb - 1, nb - 1)):
            want = arr[bx * cfg.tile:(bx + 1) * cfg.tile,
                       by * cfg.tile:(by + 1) * cfg.tile]
            got = idx.pyr_tiles[off + bx * nb + by]
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        off += nb * nb
    # total mass is preserved level by level
    assert int(idx.pyr_tiles.sum()) == 300 * cfg.levels
