"""Mixture-of-Experts with GShard-style grouped one-hot dispatch.

Routing is standard softmax top-k (NOT active search: with <=60 experts a
grid index is strictly slower than a dense arg-top-k — DESIGN.md §5).

Dispatch: tokens are split into groups of `group_size`; capacity per group is
C = ceil(g * top_k / E * capacity_factor).  The dispatch/combine tensors are
(G, g, E, C) so their size is LINEAR in tokens (g, not T, multiplies E*C).
Experts are sharded over the 'model' axis (EP); `n_padded` dummy experts make
E divisible by the axis (router never selects them: their logits are -inf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig, MoEConfig
from repro.parallel.axes import constrain


def init_moe(key, cfg: ModelConfig) -> dict:
    mo = cfg.moe
    d, e, de = cfg.d_model, mo.n_total, mo.d_expert
    keys = jax.random.split(key, 5)
    params = {
        "router": L.dense_init(keys[0], (d, e), fan_in=d),
        "wi": L.dense_init(keys[1], (e, d, de), fan_in=d),
        "wg": L.dense_init(keys[2], (e, d, de), fan_in=d),
        "wo": L.dense_init(keys[3], (e, de, d), fan_in=de),
    }
    if mo.shared_d_ff:
        params["shared"] = L.init_mlp(keys[4], d, mo.shared_d_ff)
    return params


def moe_block(params: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss ()).  Token order preserved."""
    mo: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    g = min(mo.group_size, t)
    ng = -(-t // g)
    t_pad = ng * g
    e, k = mo.n_total, mo.top_k
    cap = max(4, int(round(g * k / max(mo.n_experts, 1) * mo.capacity_factor)))

    xt = x.reshape(t, d)
    if t_pad != t:
        xt = jnp.pad(xt, ((0, t_pad - t), (0, 0)))
    xt = xt.reshape(ng, g, d).astype(L.ACT_DTYPE)
    logits = jnp.einsum("Ggd,de->Gge", xt, params["router"].astype(xt.dtype))
    logits = logits.astype(jnp.float32)
    if mo.n_padded:
        pad_mask = jnp.arange(e) >= mo.n_experts
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                        # (G, g, E)

    top_w, top_i = jax.lax.top_k(probs, k)                         # (G, g, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                              # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=2), axis=(0, 1)
    )
    aux = jnp.sum(me * ce) * (mo.n_experts**2) / max(k, 1)

    # GShard positions: slot-major cumsum so first choices win capacity races
    mask = jax.nn.one_hot(top_i, e, dtype=jnp.float32)             # (G, g, k, E)
    mask_sm = jnp.moveaxis(mask, 2, 1).reshape(ng, k * g, e)       # slot-major
    ranks_sm = jnp.cumsum(mask_sm, axis=1) - mask_sm               # rank BEFORE self
    ranks = jnp.moveaxis(ranks_sm.reshape(ng, k, g, e), 1, 2)      # (G, g, k, E)
    rank_of = jnp.sum(ranks * mask, axis=-1)                       # (G, g, k)
    keep = rank_of < cap

    # dispatch/combine: merge the k slots (disjoint experts per token)
    rank_i = jnp.where(keep, rank_of, cap).astype(jnp.int32)       # cap -> dropped
    oh_cap = jax.nn.one_hot(rank_i, cap, dtype=jnp.float32)        # (G, g, k, C)
    dispatch = jnp.einsum("GgkE,GgkC->GgEC", mask, oh_cap)         # 0/1
    combine = jnp.einsum("GgkE,GgkC,Ggk->GgEC", mask, oh_cap, top_w)

    xe = jnp.einsum("GgEC,Ggd->GECd", dispatch.astype(xt.dtype), xt)
    xe = constrain(xe, "batch", "experts", None, "embed")
    hi = jnp.einsum("GECd,Edf->GECf", xe, params["wg"].astype(xt.dtype))
    gi = jnp.einsum("GECd,Edf->GECf", xe, params["wi"].astype(xt.dtype))
    act = jax.nn.silu(gi.astype(jnp.float32)).astype(xt.dtype) * hi
    ye = jnp.einsum("GECf,Efd->GECd", act, params["wo"].astype(xt.dtype))
    ye = constrain(ye, "batch", "experts", None, "embed")
    y = jnp.einsum("GgEC,GECd->Ggd", combine.astype(xt.dtype), ye)
    y = constrain(y, "batch", None, "embed")

    if "shared" in params:
        sh = params["shared"]
        y = y + L.swiglu(xt, sh["wi"], sh["wg"], sh["wo"])

    y = y.reshape(t_pad, d)[:t]
    return y.reshape(b, s, d), aux
