"""Render the CI perf artifacts (BENCH_kernels.json / BENCH_e2e.json /
BENCH_mutation.json / BENCH_convergence.json / BENCH_accuracy.json /
BENCH_serve.json) into the
markdown throughput table embedded in README.md between the
`<!-- BENCH TABLE BEGIN/END -->` markers.

  python scripts/render_bench_table.py --artifacts bench-artifacts
  python scripts/render_bench_table.py --artifacts bench-artifacts --check

--check regenerates the table and fails (exit 1) when the README's table
STRUCTURE drifted — rows/columns/labels out of sync with what the current
benchmarks emit (numeric cells are masked before comparing, so timing noise
never fails CI; adding a backend or a bench without re-rendering does).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

BEGIN = "<!-- BENCH TABLE BEGIN -->"
END = "<!-- BENCH TABLE END -->"
NUM_RE = re.compile(r"-?\d[\d,]*\.?\d*x?")


def _load(art_dir: str, name: str) -> dict | None:
    path = os.path.join(art_dir, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def render(art_dir: str) -> str:
    rows = [
        "| bench | metric | value |",
        "|---|---|---|",
    ]

    kern = _load(art_dir, "BENCH_kernels.json")
    if kern and "count_paths" in kern:
        cp = kern["count_paths"]
        rows.append(f"| kernels | stacked counts/s (L={cp['levels']}) | "
                    f"{cp['stacked_counts_per_s']:,.0f} |")
        rows.append(f"| kernels | level-scheduled counts/s (L={cp['levels']}) | "
                    f"{cp['level_scheduled_counts_per_s']:,.0f} |")
        rows.append(f"| kernels | level-scheduler speedup | "
                    f"{cp['speedup']:.1f}x |")
    if kern and "candidate_paths" in kern:
        cd = kern["candidate_paths"]
        rows.append(f"| kernels | fused csr-topk cands/s (interpret) | "
                    f"{cd['fused_cands_per_s']:,.0f} |")
        rows.append(f"| kernels | gather+topk cands/s (interpret) | "
                    f"{cd['gather_cands_per_s']:,.0f} |")
        rows.append(f"| kernels | candidate-stage bytes, gather → fused | "
                    f"{cd['gather_intermediate_bytes']:,} → "
                    f"{cd['fused_intermediate_bytes']:,} "
                    f"({cd['intermediate_bytes_reduction']:,.0f}x) |")

    e2e = _load(art_dir, "BENCH_e2e.json")
    if e2e:
        for name, rec in sorted(e2e.get("backends", {}).items()):
            cb = rec.get("candidate_stage_bytes")
            extra = "" if cb is None else f" (cand. bytes {cb:,})"
            rows.append(
                f"| e2e | `{name}` queries/s | "
                f"{rec['queries_per_s']:,.1f}{extra} |"
            )

    mu = _load(art_dir, "BENCH_mutation.json")
    if mu:
        rows.append(f"| mutation | inserts/s (batch {mu['insert_batch']}, "
                    f"N={mu['n']:,}) | {mu['inserts_per_s']:,.0f} |")
        rows.append(f"| mutation | insert vs rebuild speedup | "
                    f"{mu['speedup_insert_vs_rebuild']:.1f}x |")
        rows.append(f"| mutation | insert+snapshot vs rebuild | "
                    f"{mu['speedup_with_snapshot']:.1f}x |")
        rows.append(f"| mutation | post-insert queries/s | "
                    f"{mu['post_insert_qps']:,.1f} |")
        rows.append(f"| mutation | parity vs rebuild | "
                    f"{mu['parity_incremental_vs_rebuild']} |")

    conv = _load(art_dir, "BENCH_convergence.json")
    if conv and "adaptive" in conv:
        ad = conv["adaptive"]
        rows.append(f"| convergence | mean Eq.-1 iters, fixed r0 → adaptive | "
                    f"{ad['baseline']['mean_iters']:.2f} → "
                    f"{ad['adaptive']['mean_iters']:.2f} "
                    f"({ad['iterations_saved']} saved) |")
        rows.append(f"| convergence | converged frac (adaptive) | "
                    f"{ad['adaptive']['converged_frac']:.3f} "
                    f"(p99 iters {ad['adaptive']['p99_iters']:.0f}) |")
        rows.append(f"| convergence | tile DMAs skipped (early exit) | "
                    f"{ad['adaptive']['tile_dmas_skipped']:,} / "
                    f"{ad['always_on_tile_dmas']:,} "
                    f"({ad['tile_dmas_skipped_frac']:.0%}) |")
        rows.append(f"| convergence | schedule parity vs jnp oracle | "
                    f"{ad['parity_adaptive_vs_jnp_oracle']} |")

    acc = _load(art_dir, "BENCH_accuracy.json")
    if acc and "quantized" in acc:
        qz = acc["quantized"]
        for name, rec in sorted(qz.get("backends", {}).items()):
            rows.append(f"| accuracy | `{name}` recall@{qz['k']} vs exact | "
                        f"{rec['recall_at_k']:.3f} |")
        q8 = qz.get("backends", {}).get("pallas_q8", {})
        if "shortlist_hit_frac" in q8:
            rows.append(f"| accuracy | q8 shortlist ⊇ exact top-{qz['k']} "
                        f"frac (rerank_k={qz['rerank_k']}) | "
                        f"{q8['shortlist_hit_frac']:.3f} |")
        cb = qz.get("candidate_bytes")
        if cb:
            rows.append(f"| accuracy | candidate-stage bytes, fp32 → q8 | "
                        f"{cb['fp32']:,} → {cb['q8']:,} "
                        f"({cb['reduction_x']:.1f}x) |")

    srv = _load(art_dir, "BENCH_serve.json")
    if srv and "queue" in srv:
        q = srv["queue"]
        rows.append(f"| serve | queue latency p50 / p99 | "
                    f"{q['p50_latency_ms']:,.1f} ms / "
                    f"{q['p99_latency_ms']:,.1f} ms |")
        rows.append(f"| serve | queue throughput | {q['qps']:,.1f} q/s "
                    f"(mean batch {q['mean_batch_rows']:.1f} rows, "
                    f"pad {q['pad_frac']:.0%}) |")
        rows.append(f"| serve | insert backlog peak → applied | "
                    f"{q['insert_backlog_peak']:,} → "
                    f"{q['inserts_applied']:,} rows |")
        rows.append(f"| serve | compaction pauses | {q['compactions']} "
                    f"({q['compact_pause_s']:.3f} s) |")
        rows.append(f"| serve | queue parity vs direct search | "
                    f"{q['parity_queue_vs_direct']} |")

    if len(rows) == 2:
        rows.append("| (no artifacts found) | — | — |")
    return "\n".join(rows)


def _mask_numbers(table: str) -> str:
    """Mask the volatile cells (numbers AND parity booleans) so the drift
    check only fires on structure, never on timing noise — and never invites
    committing a parity regression as a 'docs sync' (see _parity_problems,
    which fails those loudly instead)."""
    return re.sub(r"\b(True|False)\b", "·", NUM_RE.sub("·", table))


def _parity_problems(art_dir: str) -> list[str]:
    problems = []
    kern = _load(art_dir, "BENCH_kernels.json")
    if kern and kern.get("candidate_paths", {}).get("parity") is False:
        problems.append("BENCH_kernels.json: fused csr_candidate_topk "
                        "diverged from the gather+candidate_topk path "
                        "(candidate_paths.parity)")
    mu = _load(art_dir, "BENCH_mutation.json")
    if mu and mu.get("parity_incremental_vs_rebuild") is not True:
        problems.append("BENCH_mutation.json: incremental insert does NOT "
                        "match rebuild (parity_incremental_vs_rebuild)")
    e2e = _load(art_dir, "BENCH_e2e.json")
    for name, rec in sorted((e2e or {}).get("backends", {}).items()):
        if rec.get("parity_vs_jnp") is False:
            problems.append(f"BENCH_e2e.json: backend {name!r} diverged "
                            f"from the jnp reference (parity_vs_jnp)")
    conv = _load(art_dir, "BENCH_convergence.json")
    ad = (conv or {}).get("adaptive") or {}
    if ad.get("parity_early_exit_vs_baseline") is False:
        problems.append("BENCH_convergence.json: early exit CHANGED the "
                        "radius schedule — the lane mask must only elide "
                        "work (parity_early_exit_vs_baseline)")
    if ad.get("parity_adaptive_vs_jnp_oracle") is False:
        problems.append("BENCH_convergence.json: adaptive batched schedule "
                        "diverged from the vmapped jnp oracle "
                        "(parity_adaptive_vs_jnp_oracle)")
    if ad and ad.get("mean_iters_reduction", 1) <= 0:
        problems.append("BENCH_convergence.json: adaptive r0 did not reduce "
                        "mean Eq.-1 iterations on the skewed-density config "
                        "(mean_iters_reduction <= 0)")
    acc = _load(art_dir, "BENCH_accuracy.json")
    qz = (acc or {}).get("quantized") or {}
    floor = qz.get("recall_floor")
    q8 = qz.get("backends", {}).get("pallas_q8", {})
    if floor is not None and q8.get("recall_at_k", 1.0) < floor:
        problems.append(
            f"BENCH_accuracy.json: pallas_q8 recall@{qz.get('k')} "
            f"{q8['recall_at_k']:.3f} dropped below the recorded floor "
            f"{floor} (quantized.backends.pallas_q8.recall_at_k)"
        )
    bfloor = qz.get("bytes_reduction_floor")
    red = qz.get("candidate_bytes", {}).get("reduction_x")
    if bfloor is not None and red is not None and red < bfloor:
        problems.append(
            f"BENCH_accuracy.json: q8 candidate-stage bytes reduction "
            f"{red:.2f}x fell below the floor {bfloor}x "
            f"(quantized.candidate_bytes.reduction_x)"
        )
    for name, rec in sorted(qz.get("backends", {}).items()):
        if rec.get("parity_vs_jnp") is False:
            problems.append(
                f"BENCH_accuracy.json: exact backend {name!r} lost "
                f"bit-parity with the fused reference on the quantized "
                f"config (quantized.backends.{name}.parity_vs_jnp)"
            )
    srv = _load(art_dir, "BENCH_serve.json")
    if srv and srv.get("queue", {}).get("parity_queue_vs_direct") is False:
        problems.append("BENCH_serve.json: dynamic-batching queue results "
                        "diverged from a direct unpadded search "
                        "(queue.parity_queue_vs_direct)")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default=".",
                    help="directory holding the BENCH_*.json artifacts")
    ap.add_argument("--readme", default="README.md")
    ap.add_argument("--check", action="store_true",
                    help="fail when the README table structure drifted "
                         "instead of rewriting it")
    args = ap.parse_args()

    table = render(args.artifacts)
    with open(args.readme) as f:
        doc = f.read()
    if BEGIN not in doc or END not in doc:
        print(f"[render_bench_table] {args.readme} is missing the "
              f"{BEGIN} / {END} markers", file=sys.stderr)
        return 1

    block_re = re.compile(re.escape(BEGIN) + r"\n(.*?)" + re.escape(END),
                          flags=re.S)
    current = block_re.search(doc).group(1).strip()

    if args.check:
        parity = _parity_problems(args.artifacts)
        if parity:
            print("[render_bench_table] PARITY REGRESSION (this is a "
                  "correctness failure, NOT a docs-sync problem — do not "
                  "re-render the table to silence it):", file=sys.stderr)
            for p in parity:
                print(f"  {p}", file=sys.stderr)
            return 1
        if _mask_numbers(current) != _mask_numbers(table):
            print("[render_bench_table] README bench table is out of sync "
                  "with the benchmark output (structure drift).  Run:\n"
                  "  python scripts/render_bench_table.py --artifacts <dir>\n"
                  "and commit the result.  Diff (numbers masked):",
                  file=sys.stderr)
            for a, b in zip(
                (_mask_numbers(current) + "\n" * 99).splitlines(),
                (_mask_numbers(table) + "\n" * 99).splitlines(),
            ):
                if a != b:
                    print(f"  README : {a}\n  bench  : {b}", file=sys.stderr)
            return 1
        print("[render_bench_table] README table structure is in sync")
        return 0

    doc = block_re.sub(f"{BEGIN}\n{table}\n{END}", doc)
    with open(args.readme, "w") as f:
        f.write(doc)
    print(f"[render_bench_table] wrote {len(table.splitlines())} rows "
          f"into {args.readme}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
