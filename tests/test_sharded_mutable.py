"""The sharded mutable serving tier (core/distributed.py + the facade's
sharded insert/delete/snapshot).

Headline invariants, each checked bit-for-bit against a numpy oracle or an
unsharded rebuild — mirroring tests/test_truncation.py's oracle style:

  * cell-ownership routing is a PARTITION: every point is owned by exactly
    one shard (`owner = cell_id % n_shards`), and the union of the shards'
    live id sets is exactly the inserted ids;
  * `build(P1).insert(P2).search(Q) == build(P1 u P2).search(Q)` on the
    "sharded" backend — ids, distances, AND the Eq.-1 stat fields — across
    metrics, grid corners, and skewed/uniform densities;
  * delete parity vs a rebuild of the survivors;
  * `snapshot()` reproduces the unsharded `build_index` CSR order exactly;
  * the global top-k merge breaks distance ties by GLOBAL ID, not shard
    position;
  * a shard-local compaction leaves sibling shard states untouched.

The file runs on however many devices the process sees: 1 in the default
tier, 8 under the CI `fast-tests (8 virtual devices)` job
(XLA_FLAGS=--xla_force_host_platform_device_count=8), which is the fence
the multi-shard paths answer to.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as hst
from jax.sharding import Mesh

from repro import api
from repro.core import distributed as D
from repro.core.grid import GridConfig, build_index, cell_id_of
from repro.core.projection import identity_projection, to_grid_coords

CFG = GridConfig(grid_size=64, tile=8, n_classes=3, window=16, row_cap=32,
                 r0=4, k_slack=2.0)


@pytest.fixture(autouse=True, scope="module")
def _fresh_jit_caches():
    # This module compiles many one-off shapes (per-shard snapshots grow
    # after every insert round) on top of whatever the rest of the tier has
    # already cached; on jaxlib 0.4.37's CPU backend that combination can
    # segfault inside backend_compile.  Starting from empty caches keeps the
    # module's compilation workload self-contained.
    jax.clear_caches()
    yield


def _mesh() -> Mesh:
    return Mesh(np.asarray(jax.devices()), ("data",))


def _data(rng, n, scale=1.0):
    pts = jnp.asarray(rng.normal(size=(n, 2)) * scale, jnp.float32)
    labels = jnp.asarray(rng.integers(0, 3, size=n), jnp.int32)
    return pts, labels


def _corner_queries(rng, pts, b=8):
    """Half random, half at the data extents (clamped grid-corner windows)."""
    lo = float(jnp.min(pts))
    hi = float(jnp.max(pts))
    rand = rng.normal(size=(b // 2, 2)).astype(np.float32)
    corners = np.asarray(
        [[lo, lo], [hi, hi], [lo, hi], [hi, lo]], np.float32
    )[: b - b // 2]
    return jnp.asarray(np.concatenate([rand, corners], axis=0))


def _assert_results_equal(a, b, msg=""):
    for f in api.SearchResult._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg}:{f}",
        )


def _assert_index_equal(a, b):
    for f in ("points_sorted", "coords_sorted", "labels_sorted",
              "ids_sorted", "offsets"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
        )
    assert len(a.pyramid) == len(b.pyramid)
    for lv, (pa, pb) in enumerate(zip(a.pyramid, b.pyramid)):
        np.testing.assert_array_equal(
            np.asarray(pa), np.asarray(pb), err_msg=f"pyramid[{lv}]"
        )
    assert (a.pyr_tiles is None) == (b.pyr_tiles is None)
    if a.pyr_tiles is not None:
        np.testing.assert_array_equal(
            np.asarray(a.pyr_tiles), np.asarray(b.pyr_tiles),
            err_msg="pyr_tiles",
        )


# ------------------------------------------------------- ownership oracle ----


@settings(max_examples=6, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1),
       spread=hst.sampled_from([0.05, 0.4, 1.5]))
def test_ownership_routing_is_a_partition(seed, spread):
    """Numpy oracle: owner(p) = cell_id(clamped grid coords) % n_shards.
    Every inserted id lands on EXACTLY the oracle's shard, shard id sets
    are disjoint, and their union is the full id range."""
    rng = np.random.default_rng(seed)
    pts, labels = _data(rng, 256, scale=spread)
    proj = identity_projection(pts)
    mesh = _mesh()
    n_shards = len(mesh.devices)

    # oracle straight from the projection contract, independent of
    # distributed.shard_of_points
    coords = np.asarray(to_grid_coords(proj, pts, CFG.grid_size))
    cells = np.asarray(cell_id_of(jnp.asarray(coords), CFG.padded_size))
    oracle_owner = cells % n_shards

    idx = D.build_sharded_index(pts, CFG, proj, mesh, "data", labels)
    ids = np.asarray(idx.ids_sorted)          # (S, cap)
    offs = np.asarray(idx.offsets)            # (S, G*G+1)
    shard_sets = [set(ids[s, : offs[s, -1]].tolist()) for s in range(n_shards)]

    for s, got in enumerate(shard_sets):
        want = set(np.nonzero(oracle_owner == s)[0].tolist())
        assert got == want, f"shard {s}"
    all_ids = set().union(*shard_sets)
    assert all_ids == set(range(256))
    assert sum(len(s) for s in shard_sets) == 256  # disjoint


# ----------------------------------------------------------- insert parity ---


@settings(max_examples=4, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1),
       spread=hst.sampled_from([0.05, 1.0]),
       metric=hst.sampled_from(["l2", "l1"]))
def test_sharded_insert_bitwise_parity_vs_rebuild(seed, spread, metric):
    """build(P1).insert(P2).search(Q) == build(P1 u P2).search(Q) on the
    sharded backend — every SearchResult field, plus classify — across
    metrics, densities, and grid-corner queries."""
    cfg = GridConfig(grid_size=64, tile=8, n_classes=3, window=16,
                     row_cap=32, r0=4, k_slack=2.0, metric=metric)
    rng = np.random.default_rng(seed)
    pts, labels = _data(rng, 384, scale=spread)
    proj = identity_projection(pts)
    mesh = _mesh()
    n1 = 288

    grown = api.ActiveSearcher.build_sharded(
        pts[:n1], mesh=mesh, axis="data", labels=labels[:n1], cfg=cfg,
        proj=proj,
    ).insert(pts[n1:], labels=labels[n1:])
    ref = api.ActiveSearcher.build_sharded(
        pts, mesh=mesh, axis="data", labels=labels, cfg=cfg, proj=proj)

    q = D.replicate_queries(_corner_queries(rng, pts), mesh)
    _assert_results_equal(grown.search(q, 8), ref.search(q, 8), msg=metric)
    np.testing.assert_array_equal(
        np.asarray(grown.classify(q, 8)), np.asarray(ref.classify(q, 8)))


def test_sharded_insert_parity_chunked_and_adaptive(rng):
    """The plan knobs that reorder execution (chunked streaming, adaptive
    r0 seeding) hold the same grown-vs-rebuilt parity."""
    pts, labels = _data(rng, 384)
    proj = identity_projection(pts)
    mesh = _mesh()
    grown = api.ActiveSearcher.build_sharded(
        pts[:288], mesh=mesh, axis="data", labels=labels[:288], cfg=CFG,
        proj=proj,
    ).insert(pts[288:], labels=labels[288:])
    ref = api.ActiveSearcher.build_sharded(
        pts, mesh=mesh, axis="data", labels=labels, cfg=CFG, proj=proj)
    q = D.replicate_queries(
        jnp.asarray(rng.normal(size=(8, 2)), jnp.float32), mesh)
    for kw in ({"chunk_size": 4}, {"adaptive_r0": True}):
        a = grown.with_plan(backend="sharded", **kw).search(q, 8)
        b = ref.with_plan(backend="sharded", **kw).search(q, 8)
        _assert_results_equal(a, b, msg=str(kw))


# ----------------------------------------------------------- delete parity ---


@settings(max_examples=4, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1))
def test_sharded_delete_parity_vs_rebuild_of_survivors(seed):
    rng = np.random.default_rng(seed)
    pts, labels = _data(rng, 320)
    proj = identity_projection(pts)
    mesh = _mesh()
    dead = rng.choice(320, size=64, replace=False).astype(np.int32)
    keep = np.setdiff1d(np.arange(320), dead)

    pruned = api.ActiveSearcher.build_sharded(
        pts, mesh=mesh, axis="data", labels=labels, cfg=CFG, proj=proj,
    ).delete(jnp.asarray(dead))
    ref = api.ActiveSearcher.build_sharded(
        pts[keep], mesh=mesh, axis="data", labels=labels[keep], cfg=CFG,
        proj=proj, ids=jnp.asarray(keep, jnp.int32))

    q = D.replicate_queries(_corner_queries(rng, pts), mesh)
    _assert_results_equal(pruned.search(q, 8), ref.search(q, 8))


def test_sharded_delete_strict_accounting(rng):
    pts, labels = _data(rng, 128)
    proj = identity_projection(pts)
    s = api.ActiveSearcher.build_sharded(
        pts, mesh=_mesh(), axis="data", labels=labels, cfg=CFG, proj=proj)
    with pytest.raises(KeyError, match="not live"):
        s.delete(jnp.asarray([3, 999], jnp.int32))
    # lenient half-delete then strict re-delete of the same id
    s2 = s.delete(jnp.asarray([3], jnp.int32))
    with pytest.raises(KeyError, match="not live"):
        s2.delete(jnp.asarray([3], jnp.int32))


# --------------------------------------------------------- snapshot parity ---


def test_snapshot_reproduces_unsharded_build_bitwise(rng):
    """snapshot() on a mutated sharded handle == build_index over the same
    live points — the same CSR order, pyramid, and tiles, not just the same
    search results."""
    pts, labels = _data(rng, 320)
    proj = identity_projection(pts)
    s = api.ActiveSearcher.build_sharded(
        pts[:256], mesh=_mesh(), axis="data", labels=labels[:256], cfg=CFG,
        proj=proj,
    ).insert(pts[256:], labels=labels[256:])
    snap = s.snapshot()
    assert snap.mesh is None and snap.plan.backend == "jnp"
    dense = build_index(pts, CFG, proj, labels=labels)
    _assert_index_equal(snap.index, dense)
    # and the frozen handle serves dense backends
    q = jnp.asarray(rng.normal(size=(4, 2)), jnp.float32)
    _assert_results_equal(
        snap.search(q, 8),
        api.ActiveSearcher.from_index(dense, CFG).search(q, 8))


# ------------------------------------------------------------- merge order ---


def test_merge_tiebreak_is_global_id_order():
    """Two points equidistant from the query but in different cells (hence
    possibly different shards): the merged top-k must order the tie by
    GLOBAL id, regardless of shard position or CSR order.  Ids are assigned
    so id order DISAGREES with CSR/shard order — a shard-position merge
    (the old lax.top_k) would return [7, 3]."""
    cfg = GridConfig(grid_size=32, tile=8, window=16, row_cap=16, r0=4,
                     k_slack=2.0)
    # two far anchors pin the projection extents so the tied pair stays
    # inside ONE candidate window around the origin
    pts = jnp.asarray([[0.5, 0.0], [-0.5, 0.0], [4.0, 4.0], [-4.0, -4.0]],
                      jnp.float32)
    # identity projection: (-0.5,0) gets the LOWER cell id, so CSR/shard
    # order is [(-0.5,0), (0.5,0)] = ids [7, 3]
    proj = identity_projection(pts)
    s = api.ActiveSearcher.build_sharded(
        pts, mesh=_mesh(), axis="data", cfg=cfg, proj=proj,
        ids=jnp.asarray([3, 7, 11, 12], jnp.int32))
    q = D.replicate_queries(jnp.zeros((1, 2), jnp.float32), _mesh())
    res = s.search(q, 2)
    d = np.asarray(res.dists[0])
    assert d[0] == d[1], d  # genuinely tied
    np.testing.assert_array_equal(np.asarray(res.ids[0]), [3, 7])


# ------------------------------------------------- stats + shard locality ----


def test_sharded_stats_shape_and_pad_exclusion(rng):
    pts, labels = _data(rng, 300)  # non-pow2: stacked caps are padded
    proj = identity_projection(pts)
    s = api.ActiveSearcher.build_sharded(
        pts, mesh=_mesh(), axis="data", labels=labels, cfg=CFG, proj=proj)
    st = s.stats()
    assert st["n_points"] == 300  # pad rows excluded
    grown = s.insert(pts[:16] + 0.01, labels=labels[:16])
    st2 = grown.stats()
    assert st2["n_points"] == 316
    assert st2["n_shards"] == len(jax.devices())
    assert sum(st2["shard_points"]) == 316
    assert st2["compactions"] >= 0 and st2["compact_s"] >= 0.0


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="shard locality needs >= 2 shards")
def test_shard_local_compaction_leaves_siblings_untouched(rng):
    """Overflow ONE shard's spill log: that shard compacts and retries;
    every sibling keeps its EXACT state object (no global stall, no
    rebuild)."""
    pts, labels = _data(rng, 256)
    proj = identity_projection(pts)
    mesh = _mesh()
    n_shards = len(mesh.devices)
    idx = D.build_sharded_index(pts, CFG, proj, mesh, "data", labels)
    sm = D.open_sharded(idx, CFG, spill_capacity=4)

    # batches routed ENTIRELY to shard 0 (points that already live there,
    # re-inserted in place so ownership is unchanged), repeated until the
    # base-bucket slack is exhausted and the 4-slot spill log overflows
    owner = np.asarray(D.shard_of_points(pts, CFG, proj, n_shards))
    mine = np.nonzero(owner == 0)[0][:16]
    assert len(mine) >= 8, "seed routed too few points to shard 0"
    batch = pts[mine]
    sm2, rounds = sm, 0
    while sm2.compactions == 0 and rounds < 40:
        sm2 = D.sharded_insert(sm2, CFG, batch, labels=labels[mine])
        rounds += 1
    assert sm2.compactions >= 1, f"no compaction after {rounds} rounds"
    assert sm2.compact_s > 0.0
    for s in range(1, n_shards):
        assert sm2.states[s] is sm.states[s], f"sibling {s} was touched"

    # the compacted tier still answers bit-identically to a rebuild
    union_pts = jnp.concatenate([pts] + [batch] * rounds)
    union_labels = jnp.concatenate([labels] + [labels[mine]] * rounds)
    ref = api.ActiveSearcher.build_sharded(
        union_pts, mesh=mesh, axis="data", labels=union_labels, cfg=CFG,
        proj=proj)
    got = D.stacked_snapshot(sm2, CFG, mesh, "data")
    q = D.replicate_queries(
        jnp.asarray(rng.normal(size=(8, 2)), jnp.float32), mesh)
    res = D.sharded_search(got, CFG, q, 8, mesh, "data")
    _assert_results_equal(res, ref.search(q, 8))
