"""End-to-end system behaviour: the paper's pipeline + the LM integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import active_search as act, exact
from repro.core.grid import GridConfig, build_index
from repro.core.projection import identity_projection, pca_projection
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import Engine, ServeConfig, build_datastore_from_model
from repro.core import knn_lm
from repro.models import model as M

pytestmark = pytest.mark.slow  # full model/system drills; fast tier skips

def test_paper_pipeline_accuracy(rng):
    """The paper's §3 setup at reduced scale: random 2-D points, 3 classes,
    k=11; active-search classification vs exact-kNN ground truth >= 90%."""
    pts = jnp.asarray(rng.normal(size=(4000, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 3, size=4000), jnp.int32)
    cfg = GridConfig(grid_size=512, tile=16, n_classes=3, window=48,
                     row_cap=48, r0=20, k_slack=2.0)
    idx = build_index(pts, cfg, identity_projection(pts), labels=labels)
    q = jnp.asarray(rng.normal(size=(100, 2)), jnp.float32)
    pred = act.classify(idx, cfg, q, 11)
    truth = exact.classify(q, pts, labels, 11, n_classes=3)
    acc = float(jnp.mean((pred == truth).astype(jnp.float32)))
    assert acc >= 0.9, acc


def test_high_dim_via_projection(rng):
    """Beyond-paper: 64-dim keys through a PCA projection + re-rank."""
    base = rng.normal(size=(3000, 8))
    lift = rng.normal(size=(8, 64)) * 0.5
    pts = jnp.asarray(base @ lift + rng.normal(size=(3000, 64)) * 0.05, jnp.float32)
    cfg = GridConfig(grid_size=256, tile=16, window=64, row_cap=64, r0=6,
                     k_slack=4.0)
    idx = build_index(pts, cfg, pca_projection(pts))
    q = pts[:32] + 0.01
    res = act.search(idx, cfg, q, 5)
    ex = exact.knn(q, pts, 5)
    recall = np.mean([
        len(set(np.asarray(res.ids[i]).tolist())
            & set(np.asarray(ex.ids[i]).tolist())) / 5
        for i in range(32)
    ])
    assert recall > 0.5, recall  # projection is lossy; re-rank keeps it useful


def test_serve_engine_with_knn_head(rng):
    cfg = get_smoke("internlm2-1.8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_host_mesh(1, 1)
    knn_cfg = knn_lm.KNNLMConfig(k=4)
    corpus = rng.integers(0, cfg.vocab_size, size=(8, 33), dtype=np.int32)
    store = build_datastore_from_model(cfg, params, corpus, knn_cfg)
    engine = Engine(cfg, params, mesh, ServeConfig(knn=knn_cfg, max_new_tokens=4),
                    datastore=store)
    prompts = rng.integers(0, cfg.vocab_size, size=(2, 8), dtype=np.int32)
    toks, _ = engine.generate(prompts)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_serve_greedy_deterministic(rng):
    cfg = get_smoke("internlm2-1.8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_host_mesh(1, 1)
    engine = Engine(cfg, params, mesh, ServeConfig(max_new_tokens=4))
    prompts = rng.integers(0, cfg.vocab_size, size=(2, 8), dtype=np.int32)
    t1, _ = engine.generate(prompts)
    engine2 = Engine(cfg, params, mesh, ServeConfig(max_new_tokens=4))
    t2, _ = engine2.generate(prompts)
    np.testing.assert_array_equal(t1, t2)


def test_retrieved_decode_close_to_full(rng):
    """Retrieval-memory decode == full decode when retrieval covers the whole
    cache (w + m >= T)."""
    cfg = get_smoke("internlm2-1.8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 1, 12
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    _, caches, _ = M.prefill(params, cfg, batch, cache_len=s + 2)
    tok = jnp.asarray([3], jnp.int32)
    full_logits, _, _ = M.decode_step(params, cfg, caches, tok, jnp.int32(s))
    # local window covers [s-3, s]; retrieval covers the disjoint rest [0, s-4]
    w = 4
    retrieved = jnp.arange(s - w + 1, dtype=jnp.int32)[None, :]
    ok = jnp.ones_like(retrieved, dtype=bool)
    r_logits, _, _ = M.decode_step(
        params, cfg, caches, tok, jnp.int32(s),
        retrieved=(retrieved, ok, w),
    )
    np.testing.assert_allclose(
        np.asarray(full_logits.astype(jnp.float32)),
        np.asarray(r_logits.astype(jnp.float32)), atol=0.1,
    )
