"""Batched, kernel-backed active search — the Pallas execution path.

The jnp path (`active_search.py`) runs the paper's per-query loop under
`vmap`: each query separately counts circles via `lax.switch` over pyramid
levels, gathers its CSR window row-by-row, and ranks with `lax.top_k`.  This
module executes the SAME algorithm batch-at-a-time on the purpose-built
Pallas kernels so the hot path is MXU/VPU-shaped:

  1. Eq.-1 radius adaptation for the whole batch via the LEVEL-SCHEDULED
     `kernels.ops.tile_count_multilevel` — ONE pallas_call per iteration
     that scalar-prefetches each query's (level, window) pair and DMAs its
     circle from the correct pyramid level of the flattened tile array
     (GridIndex.pyr_tiles), instead of counting every level and selecting
     from an (L, B, C) stack (the PR-1 L-fold overcount, kept as
     `batched_counts_stacked` for benchmarking);
  2. the candidate stage as a pluggable `CandidatePipeline`:
       "fused"  (default) — `kernels.ops.csr_candidate_topk` DMAs candidate
                 rows straight from the CSR-sorted store into a
                 double-buffered VMEM scratch and emits (dists, GLOBAL CSR
                 indices); nothing of size (B, w*row_cap) ever reaches HBM,
                 and record assembly is one (B, k) take per field;
       "gather" — the PR-1..4 path: one batched (B, w*row_cap) advanced-
                  index gather of four record fields, then the dense
                  `kernels.ops.candidate_topk` re-rank.  Registered as the
                  `pallas_gather` backend — benchmark baseline and second
                  oracle, exactly how `pallas_stacked` preserves the PR-1
                  counting path.

Both pipelines are bit-for-bit identical to each other and to the jnp path
(same candidate order, same clamped spans, same first-index tie breaks; see
tests/test_batched_backend.py).  `search`/`classify` also take
`chunk_size=`: serve-scale batches stream through fixed-size kernel
invocations (one static shape, bounded VMEM) instead of materializing giant
per-batch intermediates.

This module implements the `pallas` / `pallas_gather` backends of the
`repro.api` registry — hold an `ActiveSearcher` with
`ExecutionPlan(backend="pallas")` instead of calling these entry points
directly (the old `active_search.search(backend=...)` kwarg path survives
only as a deprecation shim).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import projection as proj_lib
from repro.core import pyramid as pyr
from repro.core.active_search import (
    Candidates,
    SearchResult,
    _metric_dist,
    majority_vote,
    padded_csr,
    run_chunked,
    window_spans,
)
from repro.core.grid import GridConfig, GridIndex
from repro.kernels import ops


# --------------------------------------------------------------- counting ----


def batched_counts(
    index: GridIndex,
    cfg: GridConfig,
    q_grid: jax.Array,
    radii: jax.Array,
    interpret: bool | None = None,
    active: jax.Array | None = None,
) -> jax.Array:
    """Per-class circle counts (B, C) for a batch of queries/radii.

    Pyramid counter: ONE `ops.tile_count_multilevel` pallas_call — each
    query's `level_for_radius` level and window origin are scalar-prefetched,
    so every grid program DMAs its circle from the correct pyramid level of
    the flattened tile array.  No (L, B, C) stack, no L-fold overcount.

    `active` (B,) masks lanes out of the kernel: live lanes are compacted to
    a dense grid prefix and parked lanes skip their tile DMAs entirely (the
    Eq.-1 loop passes its not-yet-converged mask here).  Live rows are
    bit-identical to the unmasked call; parked rows are 0.  The sat counter
    ignores the mask — its integral-image lookup is O(1) with no DMA to
    skip.
    """
    if cfg.counter == "sat":
        from repro.core import integral as integral_lib

        return jax.vmap(lambda q, r: integral_lib.count_linf(index.sat, q, r))(
            q_grid, radii
        )

    levels = pyr.level_for_radius(radii, cfg)  # (B,) int32
    tiles = index.pyr_tiles
    if tiles is None:
        # Every index builder lays the tiles out exactly once (build_index,
        # mutable.snapshot, ActiveSearcher.from_index); re-flattening the
        # whole pyramid here would silently tax EVERY count call, so a
        # pre-layout index is an error, not a fallback.
        raise ValueError(
            "GridIndex.pyr_tiles is missing (pre-layout index): the pallas "
            "count path needs the pyramid pre-cut into T-tiles.  Wrap the "
            "index once via repro.api.ActiveSearcher.from_index(index, cfg) "
            "or set pyr_tiles=grid.flatten_pyramid_tiles(index.pyramid, "
            "cfg.tile) instead of paying a per-call re-flatten."
        )
    return ops.tile_count_multilevel(
        tiles, q_grid, radii.astype(jnp.float32), levels, cfg.tile,
        cfg.level_nblks, metric=cfg.metric, interpret=interpret,
        active=active,
    )


def batched_counts_stacked(
    index: GridIndex,
    cfg: GridConfig,
    q_grid: jax.Array,
    radii: jax.Array,
    interpret: bool | None = None,
) -> jax.Array:
    """The PR-1 counting path: `ops.tile_count` over EVERY level, then a
    take_along_axis select from the (L, B, C) stack.  L-fold more kernel
    work than `batched_counts`; kept as the benchmark baseline and as a
    second oracle for the level-scheduled kernel."""
    if cfg.counter == "sat":
        return batched_counts(index, cfg, q_grid, radii)

    levels = pyr.level_for_radius(radii, cfg)  # (B,) int32
    per_level = jnp.stack(
        [
            ops.tile_count(
                arr, q_grid, radii.astype(jnp.float32), 1 << lv, cfg.tile,
                metric=cfg.metric, interpret=interpret,
            )
            for lv, arr in enumerate(index.pyramid)
        ],
        axis=0,
    )  # (L, B, C)
    return jnp.take_along_axis(per_level, levels[None, :, None], axis=0)[0]


def radius_search_batched(
    index: GridIndex,
    cfg: GridConfig,
    q_grid: jax.Array,
    k: int,
    interpret: bool | None = None,
    adaptive_r0: bool = False,
    early_exit: bool = True,
) -> dict[str, jax.Array]:
    """Eq. 1 for a whole batch at once — all (B,) state arrays advance in one
    `while_loop` whose body is a SINGLE level-scheduled tile_count_multilevel
    call (one pallas_call per iteration, not one per pyramid level).

    Lane-for-lane identical to `vmap(pyramid.radius_search)`: finished lanes
    freeze (masked update) while the rest keep iterating.

    early_exit=True (default) passes the not-yet-converged lane mask into the
    count kernel, so converged lanes stop paying: their tile DMAs are elided
    (parked lanes alias the last live lane's resident blocks) and the post-
    loop recount only re-counts `best`-fallback lanes — the count a converged
    lane saw at its hit iteration IS the count at its final radius (the
    kernel is a deterministic integer reduction), so it is captured in the
    loop carry instead of recounted.  early_exit=False keeps the legacy
    unmasked schedule (every lane counts every iteration + one full batch
    recount); both return bit-identical results — the parity suite pins this.

    adaptive_r0=True seeds each lane's start radius from the pyramid's top
    levels (`pyramid.seed_radius`, vmapped — the same function the jnp path
    calls, so seeds match across backends by construction) instead of the
    global cfg.r0.

    Returns the Eq.-1 stats dict plus `tile_dmas_skipped`: a scalar count of
    the 2x2-cover tile DMAs the mask elided vs the always-on schedule (0 when
    early_exit=False or the counter has no tile DMAs to skip).
    """
    b = q_grid.shape[0]
    k_hi = jnp.int32(max(k, math.ceil(k * cfg.k_slack)))
    r_max = jnp.int32(cfg.max_radius)
    sentinel = r_max + 1
    # the sat counter is an O(1) integral-image lookup — no tile DMAs exist
    # to skip, so masking would only add permute traffic
    masked = early_exit and cfg.counter == "pyramid"

    def cond(state):
        t, _r, done, _best, _n_hit, _skipped = state
        return jnp.any(jnp.logical_and(t < cfg.max_iters, jnp.logical_not(done)))

    def body(state):
        t, r, done, best, n_hit, skipped = state
        active = jnp.logical_and(t < cfg.max_iters, jnp.logical_not(done))
        n = batched_counts(
            index, cfg, q_grid, r, interpret,
            active=active if masked else None,
        ).sum(axis=-1)  # (B,) — parked lanes read 0, frozen below
        hit = jnp.logical_and(n >= k, n <= k_hi)
        best_new = jnp.where(n >= k, jnp.minimum(best, r), best)
        ratio = jnp.sqrt(k / jnp.maximum(n, 1).astype(jnp.float32))
        r_new = jnp.round(r.astype(jnp.float32) * ratio).astype(jnp.int32)
        r_new = jnp.where(n == 0, r * 2, r_new)
        r_new = jnp.clip(r_new, 1, r_max)
        r_new = jnp.where(
            jnp.logical_and(r_new == r, jnp.logical_not(hit)),
            r + jnp.where(n < k, 1, -1),
            r_new,
        )
        r_next = jnp.where(hit, r, jnp.clip(r_new, 1, r_max))
        if masked:
            # 4 cover-tile DMAs per parked lane per iteration
            skipped = skipped + 4 * jnp.sum(
                jnp.logical_not(active).astype(jnp.int32)
            )
        return (
            jnp.where(active, t + 1, t),
            jnp.where(active, r_next, r),
            jnp.where(active, hit, done),
            jnp.where(active, best_new, best),
            # a lane that hits at radius r keeps r as its final radius, so
            # the in-loop count IS the final count — capture it here
            jnp.where(jnp.logical_and(active, hit), n, n_hit),
            skipped,
        )

    if adaptive_r0:
        r0 = jax.vmap(lambda g: pyr.seed_radius(index, cfg, g, k))(q_grid)
    else:
        # GridConfig rejects out-of-range r0 eagerly, so no silent clip here
        r0 = jnp.full((b,), jnp.int32(cfg.r0), jnp.int32)
    state0 = (
        jnp.zeros((b,), jnp.int32),
        r0,
        jnp.zeros((b,), bool),
        jnp.full((b,), sentinel, jnp.int32),
        jnp.zeros((b,), jnp.int32),
        jnp.int32(0),
    )
    t, r, converged, best, n_hit, skipped = jax.lax.while_loop(
        cond, body, state0
    )

    r_final = jnp.where(converged, r, jnp.where(best <= r_max, best, r_max))
    if masked:
        # converged lanes already hold their final count (n_hit); recount
        # only the best/r_max-fallback lanes whose final radius was never
        # counted as "final" in the loop
        n_re = batched_counts(
            index, cfg, q_grid, r_final, interpret,
            active=jnp.logical_not(converged),
        ).sum(axis=-1)
        n_final = jnp.where(converged, n_hit, n_re)
        skipped = skipped + 4 * jnp.sum(converged.astype(jnp.int32))
    else:
        n_final = batched_counts(
            index, cfg, q_grid, r_final, interpret
        ).sum(axis=-1)
    return {
        "radius": r_final,
        "count": n_final,
        "iters": t,
        "converged": converged,
        "tile_dmas_skipped": skipped,
    }


# ----------------------------------------------------------------- gather ----


def gather_candidates_batched(
    index: GridIndex,
    cfg: GridConfig,
    q_grid: jax.Array,
    spans: tuple[jax.Array, jax.Array] | None = None,
) -> Candidates:
    """CSR window gather for the whole batch as ONE advanced-index gather.

    Same span math as the per-query path (`active_search.window_spans` /
    `padded_csr`), but the (B, w, row_cap) index tensor is materialized up
    front so the candidate records come back in a single (B, w*row_cap)
    gather per field.  This is the "gather" CandidatePipeline's stage — the
    fused pipeline never materializes any of it.  `spans` lets a caller that
    already computed the window spans pass them in.
    """
    w, rcap = cfg.window, cfg.row_cap
    b = q_grid.shape[0]
    pts, crd, lab, ids, n, n_pad = padded_csr(index, rcap)
    start, end = spans if spans is not None else window_spans(index, cfg, q_grid)

    j = _window_flat_indices(n_pad, cfg, start)                     # (B, w, rcap)
    ok = (j >= start[:, :, None]) & (j < end[:, :, None]) & (j < n)

    flat = j.reshape(b, w * rcap)
    return Candidates(
        points=jnp.take(pts, flat, axis=0),      # (B, w*rcap, d)
        coords=jnp.take(crd, flat, axis=0),      # (B, w*rcap, 2)
        labels=jnp.take(lab, flat, axis=0),      # (B, w*rcap)
        ids=jnp.take(ids, flat, axis=0),         # (B, w*rcap)
        valid=ok.reshape(b, w * rcap),
    )


def _window_flat_indices(n_pad: int, cfg: GridConfig, start: jax.Array):
    """Global CSR row index of every window slot: (B, w, row_cap) int32.

    THE definition of the slot -> CSR-row map (clamped span start + in-row
    offset) shared by the gather pipeline's field gather and its
    slot-to-global-index conversion — one clamp rule, never two copies.
    """
    s_cl = jnp.clip(start, 0, max(n_pad - cfg.row_cap, 0))          # (B, w)
    return s_cl[:, :, None] + jnp.arange(cfg.row_cap, dtype=jnp.int32)


# -------------------------------------------------------- candidate stage ----


@dataclasses.dataclass(frozen=True)
class CandidatePipeline:
    """One pluggable candidate stage: spans in, ranked global rows out.

    select(index, cfg, q_grid, queries, spans, k, mode, radius, interpret,
           d_chunk) -> (dists (B, k) float32 with +inf pads,
                        gidx  (B, k) int32 GLOBAL CSR rows with -1 pads)

    Every pipeline must implement the SAME masking/tie-break contract as the
    per-query jnp reference (clamped span starts, row-major candidate order,
    first-index ties), so registered pipelines are interchangeable
    bit-for-bit and the facade can treat the stage as a plan detail.
    """

    name: str
    select: Callable[..., tuple[jax.Array, jax.Array]]
    description: str = ""


_CANDIDATE_PIPELINES: dict[str, CandidatePipeline] = {}


def register_candidate_pipeline(pipeline: CandidatePipeline) -> None:
    """Register (or replace) a candidate-stage pipeline under its name."""
    _CANDIDATE_PIPELINES[pipeline.name] = pipeline


def get_candidate_pipeline(name: str) -> CandidatePipeline:
    try:
        return _CANDIDATE_PIPELINES[name]
    except KeyError:
        raise ValueError(
            f"unknown candidate pipeline {name!r}; registered: "
            f"{sorted(_CANDIDATE_PIPELINES)}"
        ) from None


def registered_candidate_pipelines() -> tuple[str, ...]:
    return tuple(sorted(_CANDIDATE_PIPELINES))


def _fused_select(index, cfg, q_grid, queries, spans, k, mode, radius,
                  interpret, d_chunk):
    """csr_candidate_topk: DMA candidate rows straight from the CSR store —
    the only HBM traffic the stage produces is the (B, k) result pair."""
    pts, crd, _lab, _ids, n, _n_pad = padded_csr(index, cfg.row_cap)
    start, end = spans
    if mode == "paper":
        return ops.csr_candidate_topk(
            crd, start, end, q_grid, k, n, cfg.row_cap, metric=cfg.metric,
            radii=radius.astype(jnp.float32), center_cells=True,
            d_chunk=d_chunk, interpret=interpret,
        )
    return ops.csr_candidate_topk(
        pts, start, end, queries.astype(jnp.float32), k, n, cfg.row_cap,
        metric=cfg.metric, d_chunk=d_chunk, interpret=interpret,
    )


def _gather_select(index, cfg, q_grid, queries, spans, k, mode, radius,
                   interpret, d_chunk):
    """gather_candidates_batched + dense candidate_topk (the PR-1..4 path),
    with the selected slots mapped back to global CSR rows so both pipelines
    share one record-assembly step."""
    cand = gather_candidates_batched(index, cfg, q_grid, spans=spans)
    if mode == "paper":
        centers = jnp.floor(cand.coords) + 0.5                  # (B, C, 2)
        gd = _metric_dist(centers, q_grid[:, None, :], cfg.metric)
        in_circle = gd <= radius[:, None].astype(jnp.float32)
        cand = cand._replace(valid=cand.valid & in_circle)
        rank_points, rank_queries = centers, q_grid
    else:
        rank_points = cand.points
        rank_queries = queries.astype(jnp.float32)

    rd = rank_points.shape[-1]
    # d_chunk=None -> reduce each candidate in ONE accumulation step, which
    # keeps the float32 sums bit-identical to the jnp path; an explicit cap
    # (ExecutionPlan.d_chunk) trades that reassociation for bounded VMEM on
    # TPU with very large d.
    dc = rd if d_chunk is None else max(1, min(d_chunk, rd))
    outd, outi = ops.candidate_topk(
        rank_points, cand.valid, rank_queries, k,
        metric=cfg.metric, d_chunk=max(dc, 1), interpret=interpret,
    )
    # slot index -> global CSR row (the SAME _window_flat_indices map the
    # gather built its flat index from), so assembly downstream needs no
    # (B, w*row_cap) fields
    n_pad = padded_csr(index, cfg.row_cap)[5]
    start, _ = spans
    j = _window_flat_indices(n_pad, cfg, start)
    flat = j.reshape(q_grid.shape[0], cfg.window * cfg.row_cap)
    gidx = jnp.take_along_axis(flat, jnp.maximum(outi, 0), axis=1)
    return outd, jnp.where(outi >= 0, gidx, -1)


register_candidate_pipeline(CandidatePipeline(
    name="fused",
    select=_fused_select,
    description="csr_candidate_topk: double-buffered DMA from the CSR "
                "store, no (B, w*row_cap, d) HBM intermediate",
))
register_candidate_pipeline(CandidatePipeline(
    name="gather",
    select=_gather_select,
    description="one-shot (B, w*row_cap) four-field gather + dense "
                "candidate_topk (benchmark baseline / second oracle)",
))


# ---------------------------------------------------- quantized (q8) stage ---


def q8_shortlist(
    index: GridIndex,
    store,  # QuantizedStore
    cfg: GridConfig,
    queries: jax.Array,
    rerank_k: int,
    spans: tuple[jax.Array, jax.Array] | None = None,
    interpret: bool | None = None,
    d_chunk: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """The coarse int8 stage alone: approx scores + global CSR shortlist.

    Exposed for tests and the accuracy bench (shortlist-hit-fraction
    instrumentation); `search_q8` is the full coarse->re-rank path.
    """
    q_grid = proj_lib.to_grid_coords(index.proj, queries, cfg.grid_size)
    start, end = spans if spans is not None else window_spans(index, cfg, q_grid)
    n = index.points_sorted.shape[0]
    return ops.csr_shortlist_q8(
        store.q_points, store.row_scales, start, end,
        queries.astype(jnp.float32), rerank_k, n, cfg.row_cap,
        metric=cfg.metric, d_chunk=d_chunk, interpret=interpret,
    )


def _q8_select(index, store, cfg, q_grid, queries, spans, k, rerank_k, mode,
               radius, interpret, d_chunk):
    """int8 coarse shortlist -> exact fp32 re-rank of `rerank_k` rows.

    NOT a CandidatePipeline: the pipeline registry promises bit-parity
    interchange, and the q8 stage promises recall instead (ISSUE: recall@k
    contract + conditional bit-parity).  Paper mode delegates to the exact
    fused stage — it ranks 2-d cell CENTERS, which are integer-plus-half by
    construction, so there is no bandwidth to win by quantizing them.

    Re-rank invariance: the shortlist is sorted ascending by global CSR row
    before the exact re-rank, so `candidate_topk`'s first-index tie-break
    means lowest-global-row — exactly the fused kernel's tie-break (its
    window enumerates valid rows in ascending CSR order).  With the same
    d_chunk decomposition both paths compute the identical
    `sqrt(max(sum, 0))`, so whenever the shortlist contains the exact
    top-k, the re-ranked (dists, gidx) are bit-identical to `pallas`
    (tests/test_quantized.py pins this).
    """
    if mode == "paper":
        return _fused_select(index, cfg, q_grid, queries, spans, k, mode,
                             radius, interpret, d_chunk)
    pts, _crd, _lab, _ids, _n, n_pad = padded_csr(index, cfg.row_cap)
    sld, sli = q8_shortlist(
        index, store, cfg, queries, rerank_k, spans=spans,
        interpret=interpret, d_chunk=d_chunk,
    )
    del sld  # approx scores only ordered the shortlist; re-rank is exact
    # stable ascending sort by global row, -1 pads parked last (n_pad is
    # strictly greater than any live row index)
    order = jnp.argsort(jnp.where(sli >= 0, sli, n_pad), axis=1)
    sl = jnp.take_along_axis(sli, order, axis=1)          # (B, rerank_k)
    valid = sl >= 0
    cand = jnp.take(pts, jnp.maximum(sl, 0), axis=0)      # (B, rerank_k, d)
    rd = pts.shape[-1]
    # mirror the fused kernel's decomposition (d_chunk=None -> one sum) so
    # float accumulation order matches bit-for-bit
    dc = rd if d_chunk is None else max(1, min(d_chunk, rd))
    outd, outi = ops.candidate_topk(
        cand, valid, queries.astype(jnp.float32), k,
        metric=cfg.metric, d_chunk=dc, interpret=interpret,
    )
    gidx = jnp.take_along_axis(sl, jnp.maximum(outi, 0), axis=1)
    return outd, jnp.where(outi >= 0, gidx, -1)


def resolve_rerank_k(cfg: GridConfig, k: int, rerank_k: int | None) -> int:
    """The shortlist length the q8 path actually runs with.

    None -> min(max(4k, 32), window*row_cap): deep enough that the exact
    top-k survives approximate ordering at CI configs, capped at the window
    (a shortlist cannot out-run its candidate pool).  Explicit values are
    validated eagerly: rerank_k < k can never return k exact rows.
    """
    cap = cfg.window * cfg.row_cap
    if rerank_k is None:
        return min(max(4 * k, 32), cap)
    if rerank_k < k:
        raise ValueError(
            f"rerank_k={rerank_k} < k={k}: the exact re-rank can only "
            f"return rows the shortlist contains"
        )
    return min(rerank_k, cap)


@partial(
    jax.jit,
    static_argnames=(
        "cfg", "k", "rerank_k", "mode", "interpret", "d_chunk", "adaptive_r0",
    ),
)
def _search_q8_impl(
    index: GridIndex,
    store,  # QuantizedStore
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    rerank_k: int,
    mode: str = "refined",
    interpret: bool | None = None,
    d_chunk: int | None = None,
    adaptive_r0: bool = False,
) -> SearchResult:
    q_grid = proj_lib.to_grid_coords(index.proj, queries, cfg.grid_size)
    stats = radius_search_batched(
        index, cfg, q_grid, k, interpret, adaptive_r0=adaptive_r0
    )
    r = stats["radius"]
    start, end = window_spans(index, cfg, q_grid)
    truncated = ((2 * r + 1) > jnp.int32(cfg.window)) | jnp.any(
        end - start > jnp.int32(cfg.row_cap), axis=-1
    )

    outd, outi = _q8_select(
        index, store, cfg, q_grid, queries, (start, end), k, rerank_k, mode,
        r, interpret, d_chunk,
    )

    _pts, _crd, lab, ids, _n, _n_pad = padded_csr(index, cfg.row_cap)
    sel_valid = jnp.isfinite(outd)
    idx = jnp.maximum(outi, 0)
    return SearchResult(
        ids=jnp.where(sel_valid, jnp.take(ids, idx), -1),
        dists=outd.astype(jnp.float32),
        labels=jnp.where(sel_valid, jnp.take(lab, idx), -1),
        valid=sel_valid,
        radius=stats["radius"],
        count=stats["count"],
        iters=stats["iters"],
        converged=stats["converged"],
        truncated=truncated,
    )


def search_q8(
    index: GridIndex,
    store,  # QuantizedStore (core.quantized.quantize_index(index, cfg))
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    rerank_k: int | None = None,
    interpret: bool | None = None,
    chunk_size: int | None = None,
    d_chunk: int | None = None,
    adaptive_r0: bool = False,
) -> SearchResult:
    """Quantized-candidate active search (the `pallas_q8` backend).

    Identical counting/span stages to `search`; the candidate stage DMAs
    the int8 store, shortlists top-`rerank_k` by approximate int32 scores,
    then exact-re-ranks the shortlist against fp32 rows.  Final (dists,
    ids) are full fp32 — approximate only in WHICH rows made the shortlist
    (recall contract; see docs/API.md).  Paper mode is exact (cell centers
    gain nothing from quantization)."""
    rk = resolve_rerank_k(cfg, k, rerank_k)
    return run_chunked(
        lambda q: _search_q8_impl(index, store, cfg, q, k, rk, mode,
                                  interpret, d_chunk, adaptive_r0),
        queries,
        chunk_size,
    )


@partial(
    jax.jit,
    static_argnames=(
        "cfg", "k", "rerank_k", "mode", "interpret", "d_chunk", "adaptive_r0",
    ),
)
def _classify_q8_impl(
    index: GridIndex,
    store,  # QuantizedStore
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    rerank_k: int,
    mode: str = "refined",
    interpret: bool | None = None,
    d_chunk: int | None = None,
    adaptive_r0: bool = False,
) -> jax.Array:
    if cfg.n_classes <= 0:
        raise ValueError("classify() needs an index built with n_classes > 0")

    q_grid = proj_lib.to_grid_coords(index.proj, queries, cfg.grid_size)

    if mode == "paper":
        stats = radius_search_batched(
            index, cfg, q_grid, k, interpret, adaptive_r0=adaptive_r0
        )
        counts = batched_counts(index, cfg, q_grid, stats["radius"], interpret)
        return jnp.argmax(counts, axis=-1).astype(jnp.int32)

    res = _search_q8_impl(index, store, cfg, queries, k, rerank_k,
                          mode="refined", interpret=interpret, d_chunk=d_chunk,
                          adaptive_r0=adaptive_r0)
    refined = majority_vote(res.labels, res.valid, cfg.n_classes)
    fallback = jnp.argmax(
        batched_counts(index, cfg, q_grid, res.radius, interpret), axis=-1
    ).astype(jnp.int32)
    short = jnp.sum(res.valid.astype(jnp.int32), axis=1) < k
    return jnp.where(short | res.truncated, fallback, refined)


def classify_q8(
    index: GridIndex,
    store,  # QuantizedStore
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    rerank_k: int | None = None,
    interpret: bool | None = None,
    chunk_size: int | None = None,
    d_chunk: int | None = None,
    adaptive_r0: bool = False,
) -> jax.Array:
    """Quantized-candidate kNN classification (the `pallas_q8` backend) —
    `classify`'s contract with `search_q8` as the refined-vote stage."""
    rk = resolve_rerank_k(cfg, k, rerank_k)
    return run_chunked(
        lambda q: _classify_q8_impl(index, store, cfg, q, k, rk, mode,
                                    interpret, d_chunk, adaptive_r0),
        queries,
        chunk_size,
    )


# -------------------------------------------------------------- entry points -


@partial(
    jax.jit,
    static_argnames=(
        "cfg", "k", "mode", "interpret", "pipeline", "d_chunk", "adaptive_r0",
    ),
)
def _search_impl(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    interpret: bool | None = None,
    pipeline: CandidatePipeline | None = None,
    d_chunk: int | None = None,
    adaptive_r0: bool = False,
) -> SearchResult:
    # `pipeline` is the RESOLVED CandidatePipeline (frozen, hashed by its
    # fields, so re-registering a name retraces instead of silently serving
    # the stale jit cache); the public wrappers resolve names eagerly.
    if pipeline is None:
        pipeline = get_candidate_pipeline("fused")
    q_grid = proj_lib.to_grid_coords(index.proj, queries, cfg.grid_size)  # (B, 2)
    stats = radius_search_batched(
        index, cfg, q_grid, k, interpret, adaptive_r0=adaptive_r0
    )
    r = stats["radius"]
    start, end = window_spans(index, cfg, q_grid)                   # (B, w)
    truncated = ((2 * r + 1) > jnp.int32(cfg.window)) | jnp.any(
        end - start > jnp.int32(cfg.row_cap), axis=-1
    )

    outd, outi = pipeline.select(
        index, cfg, q_grid, queries, (start, end), k, mode, r, interpret,
        d_chunk,
    )

    # record assembly: one (B, k) take per field from the padded CSR arrays
    _pts, _crd, lab, ids, _n, _n_pad = padded_csr(index, cfg.row_cap)
    sel_valid = jnp.isfinite(outd)
    idx = jnp.maximum(outi, 0)
    return SearchResult(
        ids=jnp.where(sel_valid, jnp.take(ids, idx), -1),
        dists=outd.astype(jnp.float32),
        labels=jnp.where(sel_valid, jnp.take(lab, idx), -1),
        valid=sel_valid,
        radius=stats["radius"],
        count=stats["count"],
        iters=stats["iters"],
        converged=stats["converged"],
        truncated=truncated,
    )


def search(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    interpret: bool | None = None,
    chunk_size: int | None = None,
    pipeline: str = "fused",
    d_chunk: int | None = None,
    adaptive_r0: bool = False,
) -> SearchResult:
    """Batched kernel-backed active search: queries (B, d) -> SearchResult
    with leading B.  Same result contract as the facade's
    `ActiveSearcher.search` (repro.api), which is how callers should reach
    this path (`ExecutionPlan(backend="pallas")`, or "pallas_gather" for the
    gather-pipeline baseline).

    chunk_size streams the batch through fixed-size kernel invocations (one
    static shape, bounded VMEM) — results are bit-identical for any value.
    adaptive_r0 seeds each query's Eq.-1 start radius from the pyramid
    (`ExecutionPlan(adaptive_r0=True)` is the facade spelling).
    """
    pipe = get_candidate_pipeline(pipeline)  # eager: bad names raise here
    return run_chunked(
        lambda q: _search_impl(index, cfg, q, k, mode, interpret, pipe,
                               d_chunk, adaptive_r0),
        queries,
        chunk_size,
    )


@partial(
    jax.jit,
    static_argnames=(
        "cfg", "k", "mode", "interpret", "pipeline", "d_chunk", "adaptive_r0",
    ),
)
def _classify_impl(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    interpret: bool | None = None,
    pipeline: CandidatePipeline | None = None,
    d_chunk: int | None = None,
    adaptive_r0: bool = False,
) -> jax.Array:
    if cfg.n_classes <= 0:
        raise ValueError("classify() needs an index built with n_classes > 0")

    q_grid = proj_lib.to_grid_coords(index.proj, queries, cfg.grid_size)

    if mode == "paper":
        stats = radius_search_batched(
            index, cfg, q_grid, k, interpret, adaptive_r0=adaptive_r0
        )
        counts = batched_counts(index, cfg, q_grid, stats["radius"], interpret)
        return jnp.argmax(counts, axis=-1).astype(jnp.int32)

    res = _search_impl(index, cfg, queries, k, mode="refined",
                       interpret=interpret, pipeline=pipeline, d_chunk=d_chunk,
                       adaptive_r0=adaptive_r0)
    refined = majority_vote(res.labels, res.valid, cfg.n_classes)

    # same graceful degradation as the jnp path, but counted by the kernel
    fallback = jnp.argmax(
        batched_counts(index, cfg, q_grid, res.radius, interpret), axis=-1
    ).astype(jnp.int32)
    short = jnp.sum(res.valid.astype(jnp.int32), axis=1) < k
    return jnp.where(short | res.truncated, fallback, refined)


def classify(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    interpret: bool | None = None,
    chunk_size: int | None = None,
    pipeline: str = "fused",
    d_chunk: int | None = None,
    adaptive_r0: bool = False,
) -> jax.Array:
    """Batched kNN classification — same result contract as the facade's
    `ActiveSearcher.classify` (repro.api), with every count pass going
    through the level-scheduled tile_count_multilevel kernel."""
    pipe = get_candidate_pipeline(pipeline)  # eager: bad names raise here
    return run_chunked(
        lambda q: _classify_impl(index, cfg, q, k, mode, interpret, pipe,
                                 d_chunk, adaptive_r0),
        queries,
        chunk_size,
    )
