"""Pallas TPU kernel: level-scheduled circle count over the WHOLE pyramid.

The paper's "zoom" is level selection: each Eq.-1 iteration touches ONE
pyramid level per query.  `tile_count` (single-level) forced the batched
radius loop to run L stacked passes — every level for every query — and
select afterwards, an L-fold overcount.  This kernel schedules the level
INSIDE the pallas_call: the pyramid is passed as one flattened tile array
(sum_l nblk_l^2, T, T, C) — every level pre-cut into T-aligned (T, T, C)
tiles, concatenated along the leading axis — and each query's four cover
tiles are addressed by scalar-prefetched FLAT tile ids, so a single grid
program DMAs its window from the correct level.  Per-level scale is folded
into the prefetched geometry (a per-query float), not a static parameter.

Counting contract is `pyramid._count_at_level` at the query's level,
bit-for-bit for every radius: the circle mask is intersected with the
clamped [ox, ox+T) x [oy, oy+T) reference window (same window-parity rule
as tile_count), so overrunning circles never reach cells the oracle does
not scan.

Layout notes for the v5e target: one program touches 4 (T, T, C) int32
tiles + (1, C) out — with T=16..128, C<=8 this stays far under VMEM, and
VMEM use is independent of both L and B (B only widens the grid), which is
what lets serve-scale batches stream through fixed-size invocations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tile_count import circle_window_sum


def level_tile_offsets(nblks: tuple[int, ...]) -> tuple[int, ...]:
    """Start row of each level in the flattened tile array (static)."""
    offs, acc = [], 0
    for nb in nblks:
        offs.append(acc)
        acc += nb * nb
    return tuple(offs)


def _kernel(
    tid_ref,    # scalar prefetch: (B, 4) int32 flat tile ids of the 2x2 cover
    geom_ref,   # scalar prefetch: (B, 9) int32
                #   (bx0, by0, bx1, by1, ox, oy, dup_x, dup_y, live)
                #   in level cells; live=0 marks a parked (masked-out) lane
    q_ref,      # scalar prefetch: (B, 2) float32 query positions (base px)
    rs_ref,     # scalar prefetch: (B, 2) float32 (radius, 2**level)
    t00, t01, t10, t11,  # (1, T, T, C) int32 tiles (level-scheduled via tid)
    out_ref,    # (1, C) int32
    *,
    tile: int,
    metric: str,
):
    b = pl.program_id(0)
    bx0 = geom_ref[b, 0]
    by0 = geom_ref[b, 1]
    bx1 = geom_ref[b, 2]
    by1 = geom_ref[b, 3]
    oxf = geom_ref[b, 4].astype(jnp.float32)
    oyf = geom_ref[b, 5].astype(jnp.float32)
    dup_x = geom_ref[b, 6] != 0
    dup_y = geom_ref[b, 7] != 0
    live = geom_ref[b, 8] != 0
    qx = q_ref[b, 0]
    qy = q_ref[b, 1]
    r = rs_ref[b, 0]
    scale = rs_ref[b, 1]

    def masked_sum(t_ref, bx, by, zero):
        return circle_window_sum(
            t_ref[0], bx, by, qx, qy, r, scale, oxf, oyf, zero,
            tile=tile, metric=metric,
        )

    total = (
        masked_sum(t00, bx0, by0, False)
        + masked_sum(t01, bx0, by1, dup_y)
        + masked_sum(t10, bx1, by0, dup_x)
        + masked_sum(t11, bx1, by1, jnp.logical_or(dup_x, dup_y))
    )
    # parked lanes alias the anchor lane's tiles (their DMAs were elided by
    # the revisiting rule) — their geometry is stale, so blank the output
    out_ref[0, :] = jnp.where(live, total, 0)


@functools.partial(
    jax.jit, static_argnames=("tile", "nblks", "metric", "interpret")
)
def tile_count_multilevel(
    tiles: jax.Array,       # (sum_l nblk_l^2, T, T, C) int32 flattened pyramid
    queries: jax.Array,     # (B, 2) float32, base-pixel units
    radii: jax.Array,       # (B,) float32, base-pixel units
    levels: jax.Array,      # (B,) int32 pyramid level per query
    tile: int,
    nblks: tuple[int, ...],  # per-level block counts S_l // T (static)
    metric: str = "l2",
    interpret: bool = True,
    active: jax.Array | None = None,  # (B,) bool lane mask (None = all live)
) -> jax.Array:
    """Level-scheduled circle counts (B, C) in ONE pallas_call.

    Equivalent to running tile_count at each query's own level (the stacked
    (L, B, C) select), but each grid program reads only its level's window.
    See grid.flatten_pyramid_tiles for the `tiles` layout.

    `active` masks lanes OUT of the count (converged Eq.-1 lanes whose state
    is frozen by the caller): live lanes are compacted toward a dense grid
    prefix (stable argsort on the mask) and every parked lane's prefetched
    tile ids are aliased to the LAST live lane's — consecutive grid programs
    whose BlockSpec index_map resolves to the same blocks reuse the already-
    resident buffers, so the pipeline never re-issues the parked lanes' tile
    DMAs.  Parked programs write zeros (their `live` geometry flag is 0) and
    the result is scattered back to caller order, so rows of live lanes are
    bit-identical to the unmasked call and parked rows are 0.  The grid
    stays a static (B,) — only the DMA traffic shrinks with convergence.
    """
    nb_total = sum(nb * nb for nb in nblks)
    if tiles.ndim != 4 or tiles.shape[0] != nb_total or tiles.shape[1:3] != (tile, tile):
        raise ValueError(
            f"tiles shape {tiles.shape} does not match nblks={nblks}, tile={tile}"
        )
    c = tiles.shape[-1]
    b = queries.shape[0]
    n_levels = len(nblks)

    nblk_tab = jnp.asarray(nblks, jnp.int32)
    off_tab = jnp.asarray(level_tile_offsets(nblks), jnp.int32)

    lv = jnp.clip(levels.astype(jnp.int32), 0, n_levels - 1)   # (B,)
    nblk = nblk_tab[lv]
    base = off_tab[lv]
    scale = (jnp.int32(1) << lv).astype(jnp.float32)

    q = queries.astype(jnp.float32)
    r = radii.astype(jnp.float32)
    s_l = nblk * tile
    cx = jnp.floor(q[:, 0] / scale).astype(jnp.int32)
    cy = jnp.floor(q[:, 1] / scale).astype(jnp.int32)
    ox = jnp.clip(cx - tile // 2, 0, s_l - tile)
    oy = jnp.clip(cy - tile // 2, 0, s_l - tile)
    bx0 = ox // tile
    by0 = oy // tile
    dup_x = (bx0 + 1) > (nblk - 1)
    dup_y = (by0 + 1) > (nblk - 1)
    bx1 = jnp.minimum(bx0 + 1, nblk - 1)
    by1 = jnp.minimum(by0 + 1, nblk - 1)

    tid = jnp.stack(
        [
            base + bx0 * nblk + by0,
            base + bx0 * nblk + by1,
            base + bx1 * nblk + by0,
            base + bx1 * nblk + by1,
        ],
        axis=1,
    ).astype(jnp.int32)
    live = (
        jnp.ones((b,), jnp.int32) if active is None
        else active.astype(jnp.int32)
    )
    geom = jnp.stack(
        [bx0, by0, bx1, by1, ox, oy,
         dup_x.astype(jnp.int32), dup_y.astype(jnp.int32), live],
        axis=1,
    )
    rs = jnp.stack([r, scale], axis=1)

    inv = None
    if active is None:
        act = None
    else:
        act = active.astype(bool)
        # compact live lanes to a dense prefix (stable: live lanes keep their
        # relative order) and alias every parked lane's tile cover to the
        # last live lane's, so the tail of the grid revisits one resident
        # block set instead of DMAing per-lane tiles it will discard
        order = jnp.argsort(jnp.logical_not(act), stable=True)
        inv = jnp.argsort(order, stable=True)
        anchor = jnp.maximum(jnp.sum(act.astype(jnp.int32)) - 1, 0)
        tid, geom, q, rs = tid[order], geom[order], q[order], rs[order]
        tid = jnp.where(geom[:, 8:9] != 0, tid, tid[anchor][None, :])

    def im(t):
        def index_map(i, tid_ref, geom_ref, q_ref, rs_ref):
            return tid_ref[i, t], 0, 0, 0

        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, tile, tile, c), im(t)) for t in range(4)],
        out_specs=pl.BlockSpec((1, c), lambda i, *_: (i, 0)),
    )
    kernel = functools.partial(_kernel, tile=tile, metric=metric)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        interpret=interpret,
    )(tid, geom, q, rs, tiles, tiles, tiles, tiles)
    if act is None:
        return out
    # back to caller order; parked rows pinned to 0 (the kernel already
    # blanked them, the where keeps the contract explicit)
    return jnp.where(act[:, None], out[inv], 0)
