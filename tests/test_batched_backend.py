"""backend="pallas" (core/batched.py, interpret-mode kernels) vs the jnp
reference path: SearchResult parity must be bit-for-bit in refined mode."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import active_search as act
from repro.core import batched
from repro.core.grid import GridConfig, build_index
from repro.core.projection import identity_projection


def _index(rng, n=1200, n_classes=3, metric="l2", grid=128, **kw):
    pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, max(n_classes, 1), size=n), jnp.int32)
    cfg = GridConfig(grid_size=grid, tile=16, n_classes=n_classes, window=48,
                     row_cap=48, r0=8, k_slack=2.0, metric=metric, **kw)
    return pts, labels, cfg, build_index(
        pts, cfg, identity_projection(pts), labels=labels
    )


def _assert_results_equal(a: act.SearchResult, b: act.SearchResult):
    for field in act.SearchResult._fields:
        ga = np.asarray(getattr(a, field))
        gb = np.asarray(getattr(b, field))
        assert ga.shape == gb.shape, (field, ga.shape, gb.shape)
        assert ga.dtype == gb.dtype, (field, ga.dtype, gb.dtype)
        np.testing.assert_array_equal(ga, gb, err_msg=field)


def test_refined_parity_quick(rng):
    """Fast-tier parity: one index per metric, k swept inside the test so the
    interpret-mode pipeline compiles a minimal number of variants.  BOTH
    candidate pipelines — the fused csr_candidate_topk default ("pallas")
    and the gather+candidate_topk baseline ("pallas_gather") — must be
    bit-identical to the jnp reference."""
    for metric in ("l2", "l1"):
        _, _, cfg, idx = _index(rng, metric=metric)
        q = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)
        for k in (1, 8):
            ref = act.search(idx, cfg, q, k, backend="jnp")
            for backend in ("pallas", "pallas_gather"):
                got = act.search(idx, cfg, q, k, backend=backend)
                _assert_results_equal(ref, got)


@pytest.mark.slow
@pytest.mark.parametrize("k", [1, 8, 64])
@pytest.mark.parametrize("metric", ["l2", "l1"])
@pytest.mark.parametrize("b", [1, 32])
def test_refined_parity_bitforbit(rng, k, metric, b):
    """The full sweep the issue asks for: every (B, metric, k) combination
    bit-for-bit.  Each combo costs seconds of interpret-mode emulation, so
    the sweep rides in the full tier; test_refined_parity_quick keeps a
    representative subset in the fast tier."""
    _, _, cfg, idx = _index(rng, metric=metric)
    q = jnp.asarray(rng.normal(size=(b, 2)), jnp.float32)
    ref = act.search(idx, cfg, q, k, backend="jnp")
    for backend in ("pallas", "pallas_gather"):
        got = act.search(idx, cfg, q, k, backend=backend)
        _assert_results_equal(ref, got)


@pytest.mark.parametrize("k", [1, 11])
def test_paper_mode_parity(rng, k):
    """Paper mode ranks cell centers inside the final circle: the fused
    kernel's center_cells+radii path and the gather pipeline's explicit
    in-circle mask must both reproduce the jnp reference bit-for-bit."""
    _, _, cfg, idx = _index(rng)
    q = jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)
    ref = act.search(idx, cfg, q, k, mode="paper", backend="jnp")
    for backend in ("pallas", "pallas_gather"):
        got = act.search(idx, cfg, q, k, mode="paper", backend=backend)
        _assert_results_equal(ref, got)


@pytest.mark.parametrize("mode", ["refined", "paper"])
def test_classify_parity(rng, mode):
    _, _, cfg, idx = _index(rng, n=2500)
    q = jnp.asarray(rng.normal(size=(40, 2)), jnp.float32)
    ref = act.classify(idx, cfg, q, 11, mode=mode, backend="jnp")
    got = act.classify(idx, cfg, q, 11, mode=mode, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_parity_k_exceeds_candidate_window(rng):
    """k > window*row_cap valid candidates: both backends pad with -1/inf."""
    pts = jnp.asarray(rng.normal(size=(400, 2)), jnp.float32)
    cfg = GridConfig(grid_size=128, tile=16, window=8, row_cap=8, r0=4,
                     k_slack=2.0)
    idx = build_index(pts, cfg, identity_projection(pts))
    q = jnp.asarray(rng.normal(size=(4, 2)), jnp.float32)
    ref = act.search(idx, cfg, q, 100, backend="jnp")
    for backend in ("pallas", "pallas_gather"):
        got = act.search(idx, cfg, q, 100, backend=backend)
        _assert_results_equal(ref, got)
    assert not bool(np.asarray(ref.valid).all())  # some slots really padded


def test_parity_truncated_flag(rng):
    pts = jnp.asarray(rng.normal(size=(500, 2)), jnp.float32)
    cfg = GridConfig(grid_size=256, tile=16, window=8, row_cap=8, r0=4,
                     k_slack=1.5)
    idx = build_index(pts, cfg, identity_projection(pts))
    q = jnp.zeros((2, 2), jnp.float32)
    ref = act.search(idx, cfg, q, 200, backend="jnp")
    for backend in ("pallas", "pallas_gather"):
        got = act.search(idx, cfg, q, 200, backend=backend)
        _assert_results_equal(ref, got)
        assert bool(np.asarray(got.truncated).all())


def test_parity_sat_counter(rng):
    """counter="sat" routes the batched radius loop through the integral
    image instead of tile_count; results still match the jnp path."""
    _, _, cfg, idx = _index(rng, n=800, counter="sat")
    q = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)
    ref = act.search(idx, cfg, q, 7, backend="jnp")
    got = act.search(idx, cfg, q, 7, backend="pallas")
    _assert_results_equal(ref, got)


def test_batched_counts_match_scalar(rng):
    """The level-scheduled batched counts == per-query pyramid counts."""
    from repro.core import projection as proj_lib
    from repro.core import pyramid as pyr
    import jax

    pts, _, cfg, idx = _index(rng, n=1200)
    q = jnp.asarray(rng.normal(size=(16, 2)), jnp.float32)
    qg = proj_lib.to_grid_coords(idx.proj, q, cfg.grid_size)
    radii = jnp.asarray(rng.integers(1, cfg.max_radius, size=16), jnp.int32)
    got = batched.batched_counts(idx, cfg, qg, radii)
    want = jax.vmap(lambda g, r: pyr.count_in_circle(idx, cfg, g, r))(qg, radii)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("metric", ["l2", "l1"])
def test_multilevel_matches_stacked(rng, metric):
    """ONE tile_count_multilevel call == the PR-1 L-fold stack + select,
    radii spanning every level (including r == max_radius, where level
    selection clamps at the top of the pyramid)."""
    from repro.core import projection as proj_lib

    _, _, cfg, idx = _index(rng, n=1500, metric=metric)
    assert cfg.levels >= 3  # the regime the level scheduler targets
    q = jnp.asarray(rng.normal(size=(24, 2)), jnp.float32)
    qg = proj_lib.to_grid_coords(idx.proj, q, cfg.grid_size)
    radii = jnp.concatenate([
        jnp.asarray(rng.integers(1, cfg.max_radius, size=20), jnp.int32),
        jnp.full((4,), cfg.max_radius, jnp.int32),
    ])
    got = batched.batched_counts(idx, cfg, qg, radii)
    want = batched.batched_counts_stacked(idx, cfg, qg, radii)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_parity_grid_corner_queries(rng):
    """Backend parity where the count window clamps on both axes: queries
    pinned to the grid corners (far outside the data mass, so Eq. 1 drives
    radii up into clamped-window territory)."""
    pts, _, cfg, idx = _index(rng, n=600)
    lo, hi = float(jnp.min(pts)) - 1.0, float(jnp.max(pts)) + 1.0
    q = jnp.asarray(
        [[lo, lo], [hi, hi], [lo, hi], [hi, lo], [lo, 0.0], [0.0, hi]],
        jnp.float32,
    )
    ref_res = act.search(idx, cfg, q, 8, backend="jnp")
    for backend in ("pallas", "pallas_gather"):
        got = act.search(idx, cfg, q, 8, backend=backend)
        _assert_results_equal(ref_res, got)


def test_parity_max_radius_counts(rng):
    """r == max_radius: the level clamps to the top of the pyramid and the
    circle overruns the (whole-level) window — counts must still match the
    per-query oracle bit-for-bit."""
    from repro.core import projection as proj_lib
    from repro.core import pyramid as pyr
    import jax

    _, _, cfg, idx = _index(rng, n=900)
    q = jnp.asarray(rng.normal(size=(6, 2)), jnp.float32)
    qg = proj_lib.to_grid_coords(idx.proj, q, cfg.grid_size)
    radii = jnp.full((6,), cfg.max_radius, jnp.int32)
    got = batched.batched_counts(idx, cfg, qg, radii)
    want = jax.vmap(lambda g, r: pyr.count_in_circle(idx, cfg, g, r))(qg, radii)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # every point of the index is inside the max-radius circle
    assert int(np.asarray(got).sum()) > 0


def test_chunked_parity(rng):
    """chunk_size streams fixed-shape invocations; results are bit-identical
    for any chunking, on both backends (incl. a non-dividing chunk size)."""
    _, _, cfg, idx = _index(rng, n=800)
    q = jnp.asarray(rng.normal(size=(10, 2)), jnp.float32)
    for backend in ("jnp", "pallas", "pallas_gather"):
        full = act.search(idx, cfg, q, 5, backend=backend)
        chunked = act.search(idx, cfg, q, 5, backend=backend, chunk_size=4)
        _assert_results_equal(full, chunked)
    ref_cls = act.classify(idx, cfg, q, 5, backend="pallas")
    got_cls = act.classify(idx, cfg, q, 5, backend="pallas", chunk_size=3)
    np.testing.assert_array_equal(np.asarray(ref_cls), np.asarray(got_cls))
    for bad in (0, -1):
        with pytest.raises(ValueError, match="chunk_size"):
            act.search(idx, cfg, q, 5, backend="pallas", chunk_size=bad)


def test_interpret_threading(rng):
    """interpret= reaches the kernels from the public API (pallas backend)
    and is rejected on the jnp backend where it has no meaning."""
    _, _, cfg, idx = _index(rng, n=400)
    q = jnp.asarray(rng.normal(size=(4, 2)), jnp.float32)
    expl = act.search(idx, cfg, q, 3, backend="pallas", interpret=True)
    dflt = act.search(idx, cfg, q, 3, backend="pallas")  # env default (CPU: on)
    _assert_results_equal(expl, dflt)
    with pytest.raises(ValueError, match="interpret"):
        act.search(idx, cfg, q, 3, backend="jnp", interpret=True)
    with pytest.raises(ValueError, match="interpret"):
        act.classify(idx, cfg, q, 3, backend="jnp", interpret=False)


def test_gather_matches_per_query(rng):
    from repro.core import projection as proj_lib
    import jax

    pts, _, cfg, idx = _index(rng, n=900)
    q = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)
    qg = proj_lib.to_grid_coords(idx.proj, q, cfg.grid_size)
    got = batched.gather_candidates_batched(idx, cfg, qg)
    want = jax.vmap(lambda g: act.gather_candidates(idx, cfg, g))(qg)
    for field in got._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)), np.asarray(getattr(want, field)),
            err_msg=field,
        )


def test_truncated_row_overflow_parity(rng):
    """truncated must ALSO fire when a window row holds more than row_cap
    points (candidates silently dropped by the row_cap slice) even though
    the circle fits the window — same flag from the jnp path and both
    candidate pipelines."""
    # everything in a handful of cells -> one window row overflows a tiny
    # row_cap while Eq. 1 converges at a small radius
    pts = jnp.asarray(rng.normal(size=(300, 2)) * 0.01, jnp.float32)
    cfg = GridConfig(grid_size=64, tile=8, window=16, row_cap=4, r0=2,
                     k_slack=4.0)
    idx = build_index(pts, cfg, identity_projection(pts))
    q = jnp.zeros((3, 2), jnp.float32)
    ref = act.search(idx, cfg, q, 3, backend="jnp")
    assert bool(np.asarray(ref.truncated).all())
    # the overflow is the ONLY trigger here: the circle itself fits
    assert bool((2 * np.asarray(ref.radius) + 1 <= cfg.window).all())
    for backend in ("pallas", "pallas_gather"):
        got = act.search(idx, cfg, q, 3, backend=backend)
        _assert_results_equal(ref, got)


def test_classify_parity_gather_pipeline(rng):
    """classify threads the pipeline choice through _search_impl and the
    count fallback identically on both pallas variants."""
    _, _, cfg, idx = _index(rng, n=2000)
    q = jnp.asarray(rng.normal(size=(24, 2)), jnp.float32)
    ref = act.classify(idx, cfg, q, 9, backend="jnp")
    for backend in ("pallas", "pallas_gather"):
        got = act.classify(idx, cfg, q, 9, backend=backend)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_d_chunk_threading(rng):
    """ExecutionPlan.d_chunk reaches both candidate kernels: results stay
    correct (allclose dists, same neighbor ids as the default single-sum
    plan) for caps smaller than d, and a cap >= d is bit-identical."""
    from repro import api

    pts = jnp.asarray(rng.normal(size=(900, 8)), jnp.float32)
    cfg = GridConfig(grid_size=64, tile=8, window=16, row_cap=16, r0=4,
                     k_slack=2.0)
    s = api.ActiveSearcher.build(pts, cfg=cfg)
    q = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    for backend in ("pallas", "pallas_gather"):
        base = s.with_plan(backend=backend).search(q, 5)
        for dc in (3, 8, 512):
            got = s.with_plan(backend=backend, d_chunk=dc).search(q, 5)
            np.testing.assert_array_equal(
                np.asarray(base.ids), np.asarray(got.ids),
                err_msg=f"{backend} d_chunk={dc}",
            )
            np.testing.assert_allclose(
                np.asarray(base.dists), np.asarray(got.dists),
                rtol=1e-5, atol=1e-6, err_msg=f"{backend} d_chunk={dc}",
            )
            if dc >= 8:
                _assert_results_equal(base, got)


def test_unknown_candidate_pipeline_raises(rng):
    _, _, cfg, idx = _index(rng, n=100)
    q = jnp.zeros((1, 2), jnp.float32)
    with pytest.raises(ValueError, match="candidate pipeline"):
        batched.search(idx, cfg, q, 3, pipeline="telepathy")


def test_candidate_pipeline_replacement_takes_effect(rng):
    """register_candidate_pipeline's 'or replace' contract must survive the
    jit cache: names are resolved EAGERLY to the (hashable) pipeline object,
    so re-registering retraces instead of serving the stale select."""
    _, _, cfg, idx = _index(rng, n=200)
    q = jnp.asarray(rng.normal(size=(2, 2)), jnp.float32)
    base = batched.search(idx, cfg, q, 3, pipeline="fused")  # warm the cache
    orig = batched.get_candidate_pipeline("fused")
    calls = []

    def spy_select(*args, **kw):
        calls.append(1)
        return orig.select(*args, **kw)

    try:
        batched.register_candidate_pipeline(
            batched.CandidatePipeline(name="fused", select=spy_select)
        )
        got = batched.search(idx, cfg, q, 3, pipeline="fused")
        assert calls, "replaced pipeline never ran (stale jit cache)"
        _assert_results_equal(base, got)
    finally:
        batched.register_candidate_pipeline(orig)


def test_unknown_backend_raises(rng):
    _, _, cfg, idx = _index(rng, n=100)
    q = jnp.zeros((1, 2), jnp.float32)
    with pytest.raises(ValueError, match="backend"):
        act.search(idx, cfg, q, 3, backend="tpu-magic")
    with pytest.raises(ValueError, match="backend"):
        act.classify(idx, cfg, q, 3, backend="tpu-magic")
