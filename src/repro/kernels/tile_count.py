"""Pallas TPU kernel: circle-masked tile count (the paper's hot loop).

The paper's per-iteration cost is "checking all the inner pixels of the
current circle" (§3).  On TPU that becomes: DMA ONE fixed-size window of a
pyramid level from HBM into VMEM, apply the circular mask against cell
centers on the VPU, and reduce.  The window is data-dependent (it saccades to
the query), which we express with scalar-prefetched block origins driving the
BlockSpec index_map: the same level array is passed four times with index
maps (bx0+di, by0+dj), di,dj in {0,1}, so the four T-aligned tiles cover any
un-aligned T-window.

Layout notes for the v5e target: T should be a multiple of 8 (sublanes) and
the channel dim is kept innermost; with C=1..8 the (T, T, C) tile stays well
under VMEM (T=128, C=4, int32 -> 256 KiB per tile).  Validated on CPU with
interpret=True against ref.tile_count.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    origins_ref,  # scalar prefetch: (B, 2) int32 block origins (bx0, by0)
    q_ref,        # scalar prefetch: (B, 2) float32 query positions (base px)
    r_ref,        # scalar prefetch: (B,) float32 radii (base px)
    t00, t01, t10, t11,  # (T, T, C) int32 tiles
    out_ref,      # (1, C) int32
    *,
    tile: int,
    scale: int,
    nblk: int,
    metric: str,
):
    b = pl.program_id(0)
    bx0 = origins_ref[b, 0]
    by0 = origins_ref[b, 1]
    qx = q_ref[b, 0]
    qy = q_ref[b, 1]
    r = r_ref[b]

    # duplicate-tile guards: when bx0+1 is clamped by the index_map the
    # di=1 tiles alias the di=0 tiles and must contribute zero.
    dup_x = (bx0 + 1) > (nblk - 1)
    dup_y = (by0 + 1) > (nblk - 1)

    ii = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 0)
    jj = jax.lax.broadcasted_iota(jnp.float32, (tile, tile), 1)

    def masked_sum(t_ref, bx, by, zero):
        ci = ((bx * tile).astype(jnp.float32) + ii + 0.5) * scale
        cj = ((by * tile).astype(jnp.float32) + jj + 0.5) * scale
        if metric == "l1":
            inside = (jnp.abs(ci - qx) + jnp.abs(cj - qy)) <= r
        else:
            inside = (ci - qx) ** 2 + (cj - qy) ** 2 <= r * r
        inside = jnp.logical_and(inside, jnp.logical_not(zero))
        return jnp.sum(t_ref[...] * inside[:, :, None].astype(jnp.int32), axis=(0, 1))

    bx1 = jnp.minimum(bx0 + 1, nblk - 1)
    by1 = jnp.minimum(by0 + 1, nblk - 1)
    total = (
        masked_sum(t00, bx0, by0, False)
        + masked_sum(t01, bx0, by1, dup_y)
        + masked_sum(t10, bx1, by0, dup_x)
        + masked_sum(t11, bx1, by1, jnp.logical_or(dup_x, dup_y))
    )
    out_ref[0, :] = total


@functools.partial(
    jax.jit, static_argnames=("scale", "tile", "metric", "interpret")
)
def tile_count(
    level_arr: jax.Array,
    queries: jax.Array,
    radii: jax.Array,
    scale: int,
    tile: int,
    metric: str = "l2",
    interpret: bool = True,
) -> jax.Array:
    """Circle-masked counts (B, C) from one pyramid level (S, S, C).

    Contract identical to ref.tile_count (which mirrors pyramid._count_at_level).
    """
    s, _, c = level_arr.shape
    if s % tile:
        raise ValueError(f"level size {s} must be a multiple of tile {tile}")
    nblk = s // tile
    b = queries.shape[0]

    q = queries.astype(jnp.float32)
    r = radii.astype(jnp.float32)
    cx = jnp.floor(q[:, 0] / scale).astype(jnp.int32)
    cy = jnp.floor(q[:, 1] / scale).astype(jnp.int32)
    ox = jnp.clip(cx - tile // 2, 0, s - tile)
    oy = jnp.clip(cy - tile // 2, 0, s - tile)
    origins = jnp.stack([ox // tile, oy // tile], axis=1)  # (B, 2) block coords

    def im(di, dj):
        def index_map(i, origins_ref, q_ref, r_ref):
            bx = jnp.minimum(origins_ref[i, 0] + di, nblk - 1)
            by = jnp.minimum(origins_ref[i, 1] + dj, nblk - 1)
            return bx, by, 0

        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((tile, tile, c), im(0, 0)),
            pl.BlockSpec((tile, tile, c), im(0, 1)),
            pl.BlockSpec((tile, tile, c), im(1, 0)),
            pl.BlockSpec((tile, tile, c), im(1, 1)),
        ],
        out_specs=pl.BlockSpec((1, c), lambda i, *_: (i, 0)),
    )
    kernel = functools.partial(
        _kernel, tile=tile, scale=scale, nblk=nblk, metric=metric
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.int32),
        interpret=interpret,
    )(origins, q, r, level_arr, level_arr, level_arr, level_arr)
