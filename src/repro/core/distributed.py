"""Sharded active-search tier: query cost independent of N *per shard*,
with the index staying MUTABLE while it serves.

Cluster-scale layout (DESIGN.md §2): the datastore of N points is sharded
along a mesh axis; every shard builds its OWN grid over the SAME global
extents, with GLOBAL point ids.  A query (replicated) runs active search on
all shards in parallel under shard_map, then the per-shard top-k lists
(k * n_shards values — small) are merged with one all_gather + a
(distance, global id) lexicographic sort.

Per-shard query cost stays N-independent (the paper's property); the merge is
O(k * n_shards), independent of N.

Placement is by GRID-CELL OWNERSHIP: cell c lives on shard c % n_shards, so
a point's shard is a pure function of its coordinates (via the shared
projection), never of arrival order.  That determinism is what makes the
sharded tier mutable with the same headline invariant the dense tier has
(core/mutable.py):

    build_sharded(P1).insert(P2).search(Q) == build_sharded(P1 ∪ P2).search(Q)

bit for bit — both sides route every point to the same shard, per-shard
contents land in arrival order (routing preserves batch order), and the
per-shard grids are then bit-identical by the mutable subsystem's own
insert == rebuild invariant.  Each shard owns whole cells, so a `snapshot()`
merge of the per-shard CSR stores reproduces the UNSHARDED `build_index`
order exactly (`merge_to_dense`).

Mutation state is host-driven: `ShardedMutable` holds one
`mutable.MutableIndex` per shard (shapes differ per shard, so they are not
stacked).  Searches run on the stacked, pow2-PADDED snapshot
(`stacked_snapshot`): every per-shard CSR array is padded to a common
power-of-two row capacity so shard_map sees one static shape; rows past
`offsets[-1]` are unreachable (every gather derives its spans from offsets).
A shard whose spill log overflows compacts ALONE (`mutable.insert_tracked`)
— sibling shards are untouched, which keeps the pause local in a serving
tier.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import projection as proj_lib
from repro.core.active_search import SearchResult
from repro.core.grid import GridConfig, GridIndex, build_index, cell_id_of
from repro.core.projection import Projection


# ------------------------------------------------------------ cell routing ---


def shard_of_cells(cid: jax.Array, n_shards: int) -> jax.Array:
    """Deterministic grid-cell ownership: cell c lives on shard c % n_shards.

    Ownership is a PARTITION of the cells (every cell on exactly one shard),
    and a pure function of the cell — so a point's shard depends only on its
    coordinates and the shared projection, never on arrival order or on what
    else is in the index.  tests/test_sharded_mutable.py holds this to the
    partition property directly.
    """
    return cid % n_shards


def shard_of_points(
    points: jax.Array, cfg: GridConfig, proj: Projection, n_shards: int
) -> jax.Array:
    """(N,) int32 owning shard per point — the routing used by build, insert,
    and the parity oracle in the tests (same `to_grid_coords` + `cell_id_of`
    every other consumer quantizes with)."""
    coords = proj_lib.to_grid_coords(
        proj, jnp.asarray(points, jnp.float32), cfg.grid_size
    )
    return shard_of_cells(cell_id_of(coords, cfg.padded_size), n_shards)


def _pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _pad_records(idx: GridIndex, cap: int) -> GridIndex:
    """Pad the per-shard CSR record arrays to `cap` rows with dead records.

    The pad rows sit PAST offsets[-1], and every consumer (search gathers,
    snapshot slicing, `open_sharded`) derives its spans from offsets — the
    tail is never read, it only makes shard shapes equal for stacking."""
    n = idx.points_sorted.shape[0]
    pad = cap - n
    if pad == 0:
        return idx

    def ext(a, fill):
        return jnp.concatenate(
            [a, jnp.full((pad,) + a.shape[1:], fill, a.dtype)]
        )

    return idx._replace(
        points_sorted=ext(idx.points_sorted, 0.0),
        coords_sorted=ext(idx.coords_sorted, 0.0),
        labels_sorted=ext(idx.labels_sorted, -1),
        ids_sorted=ext(idx.ids_sorted, -1),
    )


def stack_shard_indexes(shards: list[GridIndex]) -> GridIndex:
    """Stack per-shard indexes into one GridIndex with a leading shard dim.

    Record arrays are padded to a common pow2 capacity first (dead tail, see
    `_pad_records`), so repeated insert/snapshot cycles hit O(log N) distinct
    stacked shapes — the same bounded-compile idiom as mutable.insert's pow2
    batch padding."""
    cap = _pow2(max(1, max(s.points_sorted.shape[0] for s in shards)))
    padded = [_pad_records(s, cap) for s in shards]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *padded)


def _place(index: GridIndex, mesh: Mesh, axis: str) -> GridIndex:
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda a: jax.device_put(a, sh), index)


def build_sharded_index(
    points: jax.Array,
    cfg: GridConfig,
    proj: Projection,
    mesh: Mesh,
    axis: str,
    labels: jax.Array | None = None,
    ids: jax.Array | None = None,
) -> GridIndex:
    """Build one grid index per `axis` shard, points routed by cell ownership.

    Returns a GridIndex whose array leaves carry a leading shard dimension of
    size mesh.shape[axis], sharded along `axis`.  Routing preserves the
    caller's point order within each shard (arrival order is a per-shard
    notion), and `ids` default to the global arange — exactly what an
    unsharded `build_index` would assign.
    """
    n_shards = mesh.shape[axis]
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if labels is None:
        labels = jnp.zeros((n,), dtype=jnp.int32)
    labels = jnp.asarray(labels, jnp.int32)
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)
    ids = jnp.asarray(ids, jnp.int32)

    owner = np.asarray(shard_of_points(points, cfg, proj, n_shards))
    shards = []
    for s in range(n_shards):
        sel = np.nonzero(owner == s)[0]  # order-preserving
        shards.append(
            build_index(points[sel], cfg, proj, labels=labels[sel],
                        ids=ids[sel])
        )
    return _place(stack_shard_indexes(shards), mesh, axis)


# -------------------------------------------------------------------- search -


@partial(
    jax.jit,
    static_argnames=("cfg", "k", "mode", "axis", "mesh", "adaptive_r0"),
)
def sharded_search(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mesh: Mesh,
    axis: str,
    mode: str = "refined",
    adaptive_r0: bool = False,
) -> SearchResult:
    """Active search over the sharded index; queries (B, d) replicated.

    Registered as backend "sharded" in the engine registry (core/engine.py):
    every shard runs its OWN per-shard ActiveSearcher handle (jnp plan) under
    shard_map, then the per-shard top-k lists are merged.  Returns the
    globally merged top-k per query (ids are global point ids).
    `adaptive_r0` seeds each shard's Eq.-1 loop from that shard's OWN
    pyramid (density differs per shard, so seeds do too — exactly like every
    other per-shard Eq.-1 quantity).

    MERGE TIE-BREAK (pinned, tests/test_mutable.py): the merged list is
    ordered by (distance, global id) — equal distances resolve to ascending
    global id, independent of which shard produced them or where the record
    sits in a shard's CSR store.  Invalid lanes (dist = +inf) sort last.
    """
    # function-level import: engine registers this module's search as a
    # backend, so a top-level import would be circular
    from repro.core import engine as eng

    local_plan = eng.ExecutionPlan(backend="jnp", adaptive_r0=adaptive_r0)

    def local_query(idx_stacked, q):
        idx = jax.tree.map(lambda a: a[0], idx_stacked)
        shard = eng.ActiveSearcher(index=idx, cfg=cfg, plan=local_plan)
        res = shard.search(q, k, mode=mode)                  # (B, k) per-shard
        d_all = lax.all_gather(res.dists, axis)               # (S, B, k)
        i_all = lax.all_gather(res.ids, axis)
        l_all = lax.all_gather(res.labels, axis)
        b = q.shape[0]
        d_flat = jnp.moveaxis(d_all, 0, 1).reshape(b, -1)     # (B, S*k)
        i_flat = jnp.moveaxis(i_all, 0, 1).reshape(b, -1)
        l_flat = jnp.moveaxis(l_all, 0, 1).reshape(b, -1)
        # lexicographic (dist, id) sort pins the tie-break to global id
        # order; lax.top_k would break ties by shard position instead
        d_sorted, i_sorted, l_sorted = lax.sort(
            (d_flat, i_flat, l_flat), dimension=1, num_keys=2,
            is_stable=True,
        )
        top_d = d_sorted[:, :k]
        ok = jnp.isfinite(top_d)
        merged = SearchResult(
            ids=jnp.where(ok, i_sorted[:, :k], -1),
            dists=top_d,
            labels=jnp.where(ok, l_sorted[:, :k], -1),
            valid=ok,
            # diagnostics: reduce across shards
            radius=lax.pmax(res.radius, axis),
            count=lax.psum(res.count, axis),
            iters=lax.pmax(res.iters, axis),
            converged=jnp.logical_and(
                lax.pmin(res.converged.astype(jnp.int32), axis) > 0, True
            ),
            truncated=lax.pmax(res.truncated.astype(jnp.int32), axis) > 0,
        )
        return merged

    in_specs = (P(axis), P())
    out_specs = P()
    fn = shard_map(
        local_query, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
    return fn(index, queries)


def replicate_queries(queries: jax.Array, mesh: Mesh) -> jax.Array:
    return jax.device_put(queries, NamedSharding(mesh, P()))


# ---------------------------------------------------------- sharded mutation -


class ShardedMutable(NamedTuple):
    """Serving-tier mutation state of a sharded handle (host-driven).

    One `mutable.MutableIndex` per shard — per-shard CSR capacities differ,
    so the states live in a host tuple rather than a stacked array tree.
    `next_id` is the GLOBAL auto-id high-water mark (per-shard next_id only
    tracks what that shard has seen).  `compactions`/`compact_s` accumulate
    the shard-LOCAL overflow compactions (`mutable.insert_tracked`): a full
    shard compacts alone while its siblings keep their states untouched —
    the serving tier's pause stays local, and benchmarks/bench_lm_serve.py
    reports it.
    """

    states: tuple
    next_id: int
    compactions: int = 0
    compact_s: float = 0.0

    @property
    def n_shards(self) -> int:
        return len(self.states)

    @property
    def n_live(self) -> int:
        return sum(int(s.n_live) for s in self.states)


def open_sharded(
    index: GridIndex, cfg: GridConfig, spill_capacity: int | None = None
) -> ShardedMutable:
    """Open a STACKED sharded index for mutation.

    Each shard's live prefix (rows before offsets[-1]; the pow2 pad tail is
    dead by construction) becomes its own `mutable.from_index` state."""
    from repro.core import mutable as mut

    n_shards = index.offsets.shape[0]
    states = []
    for s in range(n_shards):
        n_s = int(index.offsets[s, -1])
        idx_s = GridIndex(
            proj=jax.tree.map(lambda a: a[s], index.proj),
            points_sorted=index.points_sorted[s, :n_s],
            coords_sorted=index.coords_sorted[s, :n_s],
            labels_sorted=index.labels_sorted[s, :n_s],
            ids_sorted=index.ids_sorted[s, :n_s],
            offsets=index.offsets[s],
            pyramid=tuple(p[s] for p in index.pyramid),
            sat=None if index.sat is None else index.sat[s],
            pyr_tiles=None if index.pyr_tiles is None else index.pyr_tiles[s],
        )
        states.append(mut.from_index(idx_s, cfg, spill_capacity=spill_capacity))
    next_id = max(int(st.next_id) for st in states) if states else 0
    return ShardedMutable(states=tuple(states), next_id=next_id)


def sharded_insert(
    sm: ShardedMutable,
    cfg: GridConfig,
    points: jax.Array,
    labels: jax.Array | None = None,
    ids: jax.Array | None = None,
) -> ShardedMutable:
    """Route an insert batch to its owning shards and delta-insert per shard.

    Routing is order-preserving, so each shard receives its sub-batch in
    arrival order — together with cell ownership this is what makes sharded
    insert bit-identical to a sharded rebuild of the union.  A shard whose
    spill log overflows compacts ALONE (`mutable.insert_tracked`); siblings
    keep their exact state objects."""
    from repro.core import mutable as mut

    points = jnp.asarray(points, jnp.float32)
    mn = points.shape[0]
    if mn == 0:
        return sm
    if labels is None:
        labels = jnp.zeros((mn,), jnp.int32)
    labels = jnp.asarray(labels, jnp.int32)
    if ids is None:
        ids = sm.next_id + jnp.arange(mn, dtype=jnp.int32)
    ids = jnp.asarray(ids, jnp.int32)

    proj = sm.states[0].proj
    owner = np.asarray(shard_of_points(points, cfg, proj, sm.n_shards))
    states = list(sm.states)
    compactions, compact_s = sm.compactions, sm.compact_s
    for s in range(len(states)):
        sel = np.nonzero(owner == s)[0]
        if not len(sel):
            continue
        states[s], report = mut.insert_tracked(
            states[s], cfg, points[sel], labels=labels[sel], ids=ids[sel]
        )
        compactions += report.compactions
        compact_s += report.compact_s
    return ShardedMutable(
        states=tuple(states),
        next_id=max(sm.next_id, int(ids.max()) + 1),
        compactions=compactions,
        compact_s=compact_s,
    )


def sharded_delete(
    sm: ShardedMutable, cfg: GridConfig, ids: jax.Array, strict: bool = True
) -> ShardedMutable:
    """Tombstone the given global ids on whichever shards carry them.

    Matching is GLOBAL: with strict=True every asked id must be live
    somewhere (same KeyError contract as the dense `mutable.delete`), but a
    given id is allowed to live on several shards (caller-supplied id
    collisions) — every carrier dies, like the dense path."""
    from repro.core import mutable as mut

    ids = jnp.asarray(ids, jnp.int32).reshape(-1)
    if ids.shape[0] == 0:
        return sm
    present = [np.asarray(mut.ids_live_mask(st, ids)) for st in sm.states]
    if strict:
        matched_any = np.logical_or.reduce(present)
        ids_np = np.asarray(ids)
        n_asked = len(np.unique(ids_np))
        n_matched = len(np.unique(ids_np[matched_any]))
        if n_matched != n_asked:
            raise KeyError(
                f"delete: {n_asked - n_matched} of {n_asked} ids are not "
                f"live in the index (already deleted, or never inserted)"
            )
    states = list(sm.states)
    for s in range(len(states)):
        if present[s].any():
            states[s] = mut.delete(
                states[s], cfg, ids[present[s]], strict=False
            )
    return sm._replace(states=tuple(states))


def stacked_snapshot(
    sm: ShardedMutable,
    cfg: GridConfig,
    mesh: Mesh | None = None,
    axis: str | None = None,
) -> GridIndex:
    """Freeze the sharded mutation state into the stacked searchable layout
    (per-shard `mutable.snapshot`, then pow2-pad + stack; placed along the
    mesh axis when given)."""
    from repro.core import mutable as mut

    shards = [mut.snapshot(st, cfg) for st in sm.states]
    out = stack_shard_indexes(shards)
    if mesh is not None:
        out = _place(out, mesh, axis)
    return out


def merge_to_dense(index: GridIndex, cfg: GridConfig) -> GridIndex:
    """Merge a stacked sharded index into ONE dense GridIndex, bit-identical
    to `build_index` over the same points in their original arrival order.

    Every grid cell is wholly owned by one shard and routing preserved
    arrival order within each shard, so concatenating the per-shard live
    prefixes in shard order gives a point sequence whose STABLE cell-major
    sort (what `build_index` does) reproduces the unsharded CSR order
    exactly: within a cell all records come from one shard, already in
    arrival order; across cells the sort key decides, same as unsharded."""
    n_shards = index.offsets.shape[0]
    proj = jax.tree.map(lambda a: a[0], index.proj)
    pts, labs, gids = [], [], []
    for s in range(n_shards):
        n_s = int(index.offsets[s, -1])
        pts.append(index.points_sorted[s, :n_s])
        labs.append(index.labels_sorted[s, :n_s])
        gids.append(index.ids_sorted[s, :n_s])
    return build_index(
        jnp.concatenate(pts), cfg, proj,
        labels=jnp.concatenate(labs), ids=jnp.concatenate(gids),
    )


def sharded_stats(sm: ShardedMutable) -> dict:
    """Serving-tier facts for ActiveSearcher.stats() / BENCH_serve.json."""
    return {
        "n_shards": sm.n_shards,
        "shard_points": [int(s.n_live) for s in sm.states],
        "compactions": sm.compactions,
        "compact_s": sm.compact_s,
    }
