"""LM-side serving benchmark: decode throughput with and without the
active-search kNN-LM head, plus the dynamic-batching queue under a
closed-loop decode-stream workload (smoke-scale model on CPU — the
datastore search cost is the quantity of interest; the LM is constant
between the rows).

The queue workload replays the engine's OWN decode stream through
`launch.serve.DynamicBatcher`: every decode step's hidden batch arrives as
a ragged search request (1..B rows), every few steps the (hidden ->
next-token) pairs are offered to the insert backlog, and the queue serves
closed-loop — one dynamic batch at a time, draining inserts between
batches.  That is exactly the `--knn-online` serving loop, so the recorded
p50/p99 latency, qps, backlog depth, and compaction pauses are the serving
tier's, not a synthetic microbenchmark's.

Results land in BENCH_serve.json (see REPRO_BENCH_ARTIFACTS) so CI records
the serving-tier trajectory next to BENCH_mutation.json; the
`parity_queue_vs_direct` field is a drift gate (render_bench_table.py
--check fails on False).

Env knobs:
  REPRO_BENCH_QUICK=1      smallest datastore only, shorter decode stream
  REPRO_BENCH_ARTIFACTS=D  directory for BENCH_serve.json (default ".")
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro import api
from repro.configs import get_smoke
from repro.core import knn_lm
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import (
    DynamicBatcher,
    Engine,
    ServeConfig,
    build_datastore_from_model,
)
from repro.models import model as M


def _quick() -> bool:
    return os.environ.get("REPRO_BENCH_QUICK", "0") == "1"


def _queue_workload(store, knn_cfg, hiddens, tokens) -> dict:
    """Closed-loop decode-stream workload through the DynamicBatcher.

    hiddens: per-step (B, d) arrays from Engine.generate; tokens: (B, new).
    Returns the queue metrics dict for BENCH_serve.json."""
    searcher = api.ActiveSearcher.from_index(store, knn_cfg.grid)
    q = DynamicBatcher(searcher, k=knn_cfg.k, max_batch=64)

    # parity gate BEFORE any inserts: queue-padded results must be
    # bit-identical to a direct unpadded search on the same handle
    probe = jnp.asarray(np.asarray(hiddens[0][:3], np.float32))
    fut = q.submit(probe)
    q.drain()
    got, want = fut.result(timeout=0), searcher.search(probe, knn_cfg.k)
    parity = all(
        np.array_equal(np.asarray(getattr(got, f)), np.asarray(getattr(want, f)))
        for f in want._fields
    )

    rng = np.random.default_rng(0)
    b = hiddens[0].shape[0]
    # warm the pow2 shape ladder the batcher pads to, so the timed loop
    # measures serving (incl. insert drains), not jit compilation
    h0 = np.asarray(hiddens[0], np.float32)
    for w in (1, 2, 4, 8, 16):
        warm = np.repeat(h0[:1], w, axis=0)
        jax.block_until_ready(searcher.search(jnp.asarray(warm), knn_cfg.k).ids)
    # warm the insert+snapshot path on a throwaway handle (same shapes the
    # drain will hit); the timed loop then pays real insert cost, not traces
    throwaway = searcher.insert(
        jnp.asarray(h0), labels=jnp.zeros((h0.shape[0],), jnp.int32))
    jax.block_until_ready(throwaway.index.points_sorted)

    t0 = time.perf_counter()
    for step, h in enumerate(hiddens):
        h = np.asarray(h, np.float32)
        # ragged arrivals: a random non-empty prefix of the decode batch
        rows = int(rng.integers(1, b + 1))
        q.submit(h[:rows])
        if step % 4 == 3:  # periodic online growth from the decode stream
            vals = jnp.asarray(tokens[:, step + 1], jnp.int32)
            q.offer_insert(jnp.asarray(h), labels=vals)
        q.step()  # closed loop: serve as requests arrive
    q.drain()
    jax.block_until_ready(q.searcher.index.points_sorted)
    wall_s = time.perf_counter() - t0

    lat = np.asarray(q.stats["latencies_s"], np.float64)
    st = q.searcher.stats()
    return {
        "requests": q.stats["requests"],
        "request_rows": q.stats["request_rows"],
        "batches": q.stats["batches"],
        "mean_batch_rows": q.stats["batch_rows"] / max(q.stats["batches"], 1),
        "pad_rows": q.stats["pad_rows"],
        "pad_frac": q.stats["pad_rows"]
        / max(q.stats["batch_rows"] + q.stats["pad_rows"], 1),
        "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
        "qps": q.stats["request_rows"] / wall_s,
        "insert_rows_queued": q.stats["insert_rows_queued"],
        "insert_backlog_peak": q.stats["insert_backlog_peak"],
        "inserts_applied": q.stats["inserts_applied"],
        "compactions": st.get("compactions", 0),
        "compact_pause_s": st.get("compact_s", 0.0),
        "parity_queue_vs_direct": bool(parity),
    }


def main(datastore_sizes=None) -> None:
    quick = _quick()
    if datastore_sizes is None:
        datastore_sizes = (4096,) if quick else (4096, 65_536)
    max_new = 8 if quick else 16
    cfg = get_smoke("internlm2-1.8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_host_mesh(1, 1)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(8, 32), dtype=np.int32)
    csv = Csv("mode,datastore_n,decode_tok_per_s")

    engine = Engine(cfg, params, mesh, ServeConfig(max_new_tokens=max_new))
    engine.generate(prompts)  # warm
    engine.stats = {"prefill_s": 0, "decode_s": 0, "tokens": 0}
    engine.generate(prompts)
    lm_only = engine.stats["tokens"] / engine.stats["decode_s"]
    csv.row("lm_only", 0, f"{lm_only:.1f}")

    knn_cfg = knn_lm.KNNLMConfig(k=8)
    decode_rows = []
    store = hiddens = toks = None
    for n in datastore_sizes:
        corpus = rng.integers(0, cfg.vocab_size, size=(n // 64, 65), dtype=np.int32)
        store = build_datastore_from_model(cfg, params, corpus, knn_cfg)
        eng = Engine(cfg, params, mesh, ServeConfig(max_new_tokens=max_new, knn=knn_cfg),
                     datastore=store)
        eng.generate(prompts)  # warm
        eng.stats = {"prefill_s": 0, "decode_s": 0, "tokens": 0}
        toks, hiddens = eng.generate(prompts)
        tps = eng.stats["tokens"] / eng.stats["decode_s"]
        csv.row("knn_lm_active_search", store.n_points, f"{tps:.1f}")
        decode_rows.append({"datastore_n": int(store.n_points),
                            "knn_tok_per_s": tps})

    queue = _queue_workload(store, knn_cfg, hiddens, toks)
    csv.row("queue_p50_latency_ms", store.n_points,
            f"{queue['p50_latency_ms']:.2f}")
    csv.row("queue_p99_latency_ms", store.n_points,
            f"{queue['p99_latency_ms']:.2f}")
    csv.row("queue_qps", store.n_points, f"{queue['qps']:.1f}")
    csv.row("queue_insert_backlog_peak", store.n_points,
            queue["insert_backlog_peak"])
    csv.row("queue_parity_vs_direct", store.n_points,
            queue["parity_queue_vs_direct"])

    results = {
        "schema": 1, "timestamp": time.time(), "quick": quick,
        "decode": {"lm_only_tok_per_s": lm_only, "rows": decode_rows},
        "queue": queue,
    }
    art_dir = os.environ.get("REPRO_BENCH_ARTIFACTS", ".")
    path = os.path.join(art_dir, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=2)
    print(f"[bench_lm_serve] wrote {path}", flush=True)
    return csv


if __name__ == "__main__":
    main()
