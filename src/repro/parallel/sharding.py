"""Logical sharding rules -> PartitionSpecs (DESIGN.md §4).

Mesh axes: ('pod', 'data', 'model') multi-pod, ('data', 'model') single-pod.
  batch    -> ('pod', 'data')           (DP; pod composes with data)
  d_model  -> 'data' when policy.fsdp_params (FSDP/ZeRO-3 within a pod)
  heads/ff/experts/vocab/inner dims -> 'model' (TP/EP)

Optimizer state inherits the param specs, so ZeRO-1 comes for free.
Uneven dims (kv=8 over model=16, vocab % 16 != 0) rely on GSPMD padding —
valid, at some waste; the perf loop revisits the wasteful ones (§Perf).
"""

from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_axes_for(batch_size: int, mesh: Mesh, dp_only: bool = False) -> tuple:
    """Largest preferred DP axis set whose size divides `batch_size`.

    Preference: all DP axes (plus 'model' for dp_only archs — pure DP), then
    progressively smaller sets.  B=1 long-context cells end up replicated."""
    base = list(dp_axes(mesh))
    candidates: list[tuple] = []
    if dp_only and "model" in mesh.axis_names:
        candidates.append(tuple(base + ["model"]))
    for i in range(len(base) + 1):          # drop 'pod' first, then 'data'
        candidates.append(tuple(base[i:]))
    for cand in candidates:
        if not cand or batch_size % math.prod(mesh.shape[a] for a in cand) == 0:
            return cand
    return ()


def _fsdp(cfg: ModelConfig, mesh: Mesh):
    return "data" if (cfg.policy.fsdp_params and "data" in mesh.axis_names) else None


def _mdl(mesh: Mesh):
    return "model" if "model" in mesh.axis_names else None


def param_pspec(path: tuple, leaf: Any, cfg: ModelConfig, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, keyed on tree path + rank.

    dp_only archs take no tensor parallelism (the batch is sharded over every
    axis instead) but still FSDP-shard params over 'data' for memory."""
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = keys[-1]
    fsdp = _fsdp(cfg, mesh)
    mdl = None if cfg.policy.dp_only else _mdl(mesh)
    stacked = "blocks" in keys           # block params carry a leading (R,) axis
    lead: tuple = (None,) if stacked else ()
    nd = leaf.ndim - len(lead)
    in_moe = cfg.moe is not None and "ffn" in keys

    def _divides(axis, size) -> bool:
        return axis is not None and size % mesh.shape[axis] == 0

    if name == "embed":
        return P(mdl, fsdp)
    if name == "lm_head":
        return P(fsdp, mdl)
    if name in ("wq", "wk", "wv") and nd == 3:        # (d, H, hd) attn / mlstm(din,nh,hd)
        # shard the HEAD dim only when it divides; NEVER fall back to head_dim
        # (hd is the attention contraction dim — sharding it turns every
        # score matmul into an all-reduce; measured 78 s collective on the
        # musicgen train cell, EXPERIMENTS.md §Perf iteration 1)
        h = leaf.shape[len(lead) + 1]
        return P(*lead, fsdp, mdl if _divides(mdl, h) else None, None)
    if name == "wo" and nd == 3 and not in_moe:       # attn out (H, hd, d)
        h = leaf.shape[len(lead)]
        return P(*lead, mdl if _divides(mdl, h) else None, None, fsdp)
    if in_moe:
        if name == "router":
            return P(*lead, fsdp, mdl)
        if name in ("wi", "wg") and nd == 3:          # (E, d, f)
            return P(*lead, mdl, fsdp, None)
        if name == "wo" and nd == 3:                  # (E, f, d)
            return P(*lead, mdl, None, fsdp)
    if name in ("wi", "wg") and nd == 2:              # dense MLP (d, ff)
        return P(*lead, fsdp, mdl)
    if name == "wo" and nd == 2:                      # dense MLP out (ff, d)
        return P(*lead, mdl, fsdp)
    # mamba
    if name == "in_proj":
        return P(*lead, fsdp, mdl)
    if name == "out_proj":
        return P(*lead, mdl, fsdp)
    if name == "conv_w":
        return P(*lead, None, mdl)
    if name in ("conv_b", "dt_bias", "D"):
        return P(*lead, mdl)
    if name == "x_proj":
        return P(*lead, mdl, None)
    if name == "dt_proj":
        return P(*lead, None, mdl)
    if name == "A_log":
        return P(*lead, mdl, None)
    # xlstm
    if name == "up":
        return P(*lead, fsdp, mdl)
    if name == "down":
        return P(*lead, mdl, fsdp)
    if name == "wif":                                  # (din, nh, 2)
        return P(*lead, mdl, None, None)
    if name == "wx":                                   # (din, 4, din)
        return P(*lead, mdl, None, None)
    if name == "r":                                    # (nh, hd, 4, hd)
        return P(*lead, *([None] * nd))
    # norms, biases, gates
    return P(*lead, *([None] * nd))


def fit_pspec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Make `spec` legal for `shape`: every sharded dim must divide evenly.

    jit in/out shardings REQUIRE divisibility (no GSPMD padding at the pjit
    boundary).  Axes that do not divide their assigned dim are re-homed onto
    the first still-unsharded dim they DO divide (e.g. kv_heads=8 over
    model=16 moves to head_dim=128 — column parallelism inside the head), and
    dropped (replicated) only when nothing fits.
    """
    entries = list(spec) + [None] * (len(shape) - len(spec))
    norm: list[list] = []
    for e in entries[: len(shape)]:
        if e is None:
            norm.append([])
        elif isinstance(e, (tuple, list)):
            norm.append([a for a in e if a is not None])
        else:
            norm.append([e])

    placed: list[list] = []
    dropped: list = []
    for size, axes in zip(shape, norm):
        keep: list = []
        prod = 1
        for a in axes:
            asz = mesh.shape[a]
            if size % (prod * asz) == 0:
                keep.append(a)
                prod *= asz
            else:
                dropped.append(a)
        placed.append(keep)

    for a in list(dropped):
        asz = mesh.shape[a]
        for i, size in enumerate(shape):
            if not placed[i] and size % asz == 0:
                placed[i].append(a)
                dropped.remove(a)
                break

    out = []
    for k in placed:
        if not k:
            out.append(None)
        elif len(k) == 1:
            out.append(k[0])
        else:
            out.append(tuple(k))
    return P(*out)


def fit_specs(specs: Any, abstract: Any, mesh: Mesh) -> Any:
    """Apply fit_pspec leaf-wise: specs tree (P leaves) x abstract tree."""
    return jax.tree.map(
        lambda s, l: fit_pspec(s, tuple(l.shape), mesh),
        specs,
        abstract,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    raw = jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, cfg, mesh), params
    )
    return fit_specs(raw, params, mesh)


def param_shardings(params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), param_specs(params, cfg, mesh)
    )


def batch_specs(batch: Any, mesh: Mesh, cfg: ModelConfig | None = None) -> Any:
    dp_only = bool(cfg is not None and cfg.policy.dp_only)

    def spec(leaf):
        if leaf.ndim == 0:
            return P()
        dp = dp_axes_for(leaf.shape[0], mesh, dp_only)
        return fit_pspec(P(dp, *([None] * (leaf.ndim - 1))), tuple(leaf.shape), mesh)

    return jax.tree.map(spec, batch)


def cache_pspec(
    path: tuple, leaf: Any, cfg: ModelConfig, mesh: Mesh, batch_size: int | None = None
) -> P:
    """Decode-cache leaves carry a leading (R,) stack axis, then batch.

    When the batch dim cannot use all DP axes (long_500k B=1), the KV seq dim
    takes the spare DP axes instead — flash-decode style cache partitioning."""
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = keys[-1]
    if batch_size is None:
        batch_size = leaf.shape[1]
    dp = dp_axes_for(batch_size, mesh, cfg.policy.dp_only)
    spare = tuple(a for a in dp_axes(mesh) if a not in dp)
    mdl = _mdl(mesh) if not cfg.policy.dp_only else None
    if name in ("k", "v"):              # (R, B, T, Hkv, hd)
        if cfg.policy.seq_shard_cache:
            seq = (*spare, mdl) if mdl else spare
            return P(None, dp, seq if seq else None, None, None)
        # model axis: Hkv if it divides, else head_dim.  NEVER the seq dim —
        # a dynamic-update-slice at a traced position on a T-sharded cache
        # all-gathers the whole cache (measured 1.75 s collective / decode
        # step on minitron decode_32k; §Perf iteration 2).  hd-sharded decode
        # scores cost one small (B,H,T) all-reduce instead.
        hkv = leaf.shape[3]
        if mdl is not None and hkv % mesh.shape[mdl] == 0:
            return P(None, dp, spare if spare else None, mdl, None)
        return P(None, dp, spare if spare else None, None, mdl)
    if name == "conv":                   # (R, B, dconv-1, din)
        return P(None, dp, None, mdl)
    if name == "ssm":                    # (R, B, din, ds)
        return P(None, dp, mdl, None)
    if name == "c" and leaf.ndim == 5:   # mlstm (R, B, nh, hd, hd)
        return P(None, dp, None, None, None)
    if name == "n" and leaf.ndim == 4:   # mlstm (R, B, nh, hd)
        return P(None, dp, None, None)
    # slstm states (R, B, din) and mlstm scalars
    return P(None, dp, *([None] * (leaf.ndim - 2)))


def cache_specs(
    caches: Any, cfg: ModelConfig, mesh: Mesh, batch_size: int | None = None
) -> Any:
    raw = jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_pspec(path, leaf, cfg, mesh, batch_size), caches
    )
    return fit_specs(raw, caches, mesh)


def logits_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh), None, _mdl(mesh))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
