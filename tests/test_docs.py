"""Docs stay healthy: relative links in README/docs resolve and python code
blocks parse (the same check CI runs via scripts/check_docs.py)."""

import importlib.util
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(ROOT, "scripts", "check_docs.py")
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_links_resolve_and_code_blocks_parse(capsys):
    mod = _load_check_docs()
    rc = mod.main(ROOT)
    err = capsys.readouterr().err
    assert rc == 0, f"docs check failed:\n{err}"


def test_docs_exist_and_are_linked_from_readme():
    for name in ("ARCHITECTURE.md", "API.md"):
        assert os.path.exists(os.path.join(ROOT, "docs", name))
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    assert "docs/ARCHITECTURE.md" in readme and "docs/API.md" in readme


def test_check_docs_catches_broken_link_and_bad_python(tmp_path):
    """The checker actually fails on problems (not vacuously green)."""
    mod = _load_check_docs()
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[missing](docs/NOPE.md)\n\n```python\ndef broken(:\n```\n"
    )
    problems = mod.check_links(str(tmp_path / "README.md"))
    assert any("NOPE.md" in p for p in problems)
    problems = mod.check_code_blocks(str(tmp_path / "README.md"))
    assert any("does not parse" in p for p in problems)
    assert mod.main(str(tmp_path)) == 1
