"""Benchmark harness — one module per paper table/figure + system benches.

  python -m benchmarks.run              # everything
  python -m benchmarks.run --only time_vs_n accuracy
"""

from __future__ import annotations

import argparse
import os
import time
import traceback

BENCHES = [
    ("time_vs_n", "paper Fig. 3: elapsed time vs N",
     "benchmarks.bench_time_vs_n"),
    ("accuracy", "paper §3: accuracy vs exact kNN (3000^2, r0=100, k=11)",
     "benchmarks.bench_accuracy"),
    ("resolution", "paper §2: resolution trade-off",
     "benchmarks.bench_resolution"),
    ("metrics", "paper §3: L1 vs L2",
     "benchmarks.bench_metrics"),
    ("convergence", "Eq. 1 radius-loop behaviour",
     "benchmarks.bench_convergence"),
    ("kernels", "kernel microbench + interpret validation",
     "benchmarks.bench_kernels"),
    ("e2e", "facade throughput per registered backend (BENCH_e2e.json)",
     "benchmarks.bench_e2e"),
    ("mutation", "streaming insert/delete vs rebuild (BENCH_mutation.json)",
     "benchmarks.bench_mutation"),
    ("lm_serve", "kNN-LM serving throughput",
     "benchmarks.bench_lm_serve"),
    ("roofline", "roofline table from the dry-run artifact",
     "benchmarks.bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", nargs="*", default=None,
                    help=f"subset of {[b[0] for b in BENCHES]}")
    ap.add_argument("--artifacts-dir", default=None,
                    help="directory for perf artifacts (BENCH_kernels.json); "
                         "exported to benches as REPRO_BENCH_ARTIFACTS")
    args = ap.parse_args()

    if args.artifacts_dir:
        os.makedirs(args.artifacts_dir, exist_ok=True)
        os.environ["REPRO_BENCH_ARTIFACTS"] = args.artifacts_dir

    failures = 0
    for name, desc, module in BENCHES:
        if args.only and name not in args.only:
            continue
        print(f"\n=== {name}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
            print(f"--- {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"--- {name} FAILED", flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark(s) failed")


if __name__ == "__main__":
    main()
