"""The quantized candidate path (core/quantized.py + pallas_q8 backend).

Contract under test, per the candidate-stage design:

  * per-cell symmetric scales match an independent numpy oracle;
  * the int8 shortlist kernel is an EXACT match for its jnp oracle
    (integer scoring is deterministic — no allclose);
  * shortlist containment => bit parity: on every query lane whose int8
    shortlist contains ALL rows the exact fused stage returned, pallas_q8
    reproduces the `pallas` result bit-for-bit (and with a full-window
    rerank_k, on EVERY lane);
  * the store is a pure function of the snapshot, so
    build(P1).insert(P2) == build(P1 u P2) under pallas_q8 and
    mutable.quantized_snapshot equals requantizing a from-scratch rebuild.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as hst

from repro import api
from repro.core import batched
from repro.core.active_search import padded_csr, window_spans
from repro.core.grid import GridConfig, build_index, cell_id_of
from repro.core.projection import identity_projection, to_grid_coords
from repro.core.quantized import quantize_index
from repro.kernels import ops, ref
from repro.utils.quantize import QMAX

CFG = GridConfig(grid_size=64, tile=8, n_classes=3, window=8, row_cap=4,
                 r0=4, k_slack=2.0)
N, B, K = 256, 8, 3


def _build(rng, cfg=CFG, n=N, d=2, spread=1.0):
    pts = jnp.asarray(rng.normal(size=(n, d)) * spread, jnp.float32)
    labels = jnp.asarray(rng.integers(0, 3, size=n), jnp.int32)
    idx = build_index(pts, cfg, identity_projection(pts), labels=labels)
    return pts, labels, idx


def _corner_queries(rng, pts, b=B):
    d = pts.shape[1]
    lo = float(jnp.min(pts)) - 0.5
    hi = float(jnp.max(pts)) + 0.5
    corners = np.zeros((4, d), np.float32)
    corners[:, :2] = [[lo, lo], [hi, hi], [lo, hi], [hi, lo]]
    extra = rng.normal(size=(b - 4, d)) * float(jnp.std(pts))
    return jnp.asarray(np.concatenate([corners, extra]), jnp.float32)


# ------------------------------------------------------------------ store ----


def test_per_cell_scales_match_numpy_oracle(rng):
    pts, _labels, idx = _build(rng)
    store = quantize_index(idx, CFG)
    g = CFG.padded_size

    cid = np.asarray(cell_id_of(idx.coords_sorted, g))
    pts_sorted = np.asarray(idx.points_sorted)
    # float32 throughout, mirroring utils.quantize.symmetric_scale — scale
    # agreement must be EXACT or the q8 kernel and oracle drift
    want = np.full((g * g,), 1e-12, np.float32)
    for c in np.unique(cid):
        want[c] = np.maximum(
            np.abs(pts_sorted[cid == c]).max(), np.float32(1e-12)
        )
    want = want / np.float32(QMAX)

    got = np.asarray(store.cell_scales)
    occupied = np.unique(cid)
    # XLA may lower the /127 as a reciprocal multiply (1 ulp off numpy's
    # division); everything downstream uses the jnp value consistently, so
    # ulp-tight is the right bar here — not bit-equal across compilers
    np.testing.assert_allclose(got[occupied], want[occupied], rtol=2e-7)
    # row_scales broadcast the OWNING cell's scale to each CSR row
    np.testing.assert_array_equal(
        np.asarray(store.row_scales)[: len(cid), 0], got[cid]
    )
    # codes reconstruct within half a quantization step per dim
    recon = np.asarray(store.q_points[: len(cid)], np.float32) * np.asarray(
        store.row_scales
    )[: len(cid)]
    assert np.all(
        np.abs(recon - pts_sorted) <= np.asarray(store.row_scales)[: len(cid)]
    )


def test_store_is_pure_function_of_index(rng):
    """Bit-identical index -> bit-identical store (the mutability hook)."""
    _pts, _labels, idx = _build(rng)
    a, b = quantize_index(idx, CFG), quantize_index(idx, CFG)
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


# ----------------------------------------------------------------- kernel ----


@pytest.mark.parametrize("metric", ["l2", "l1"])
@pytest.mark.parametrize("d_chunk", [None, 1, 3])
def test_q8_kernel_matches_ref_oracle_exactly(rng, metric, d_chunk):
    cfg = GridConfig(grid_size=64, tile=8, n_classes=3, window=8, row_cap=4,
                     r0=4, k_slack=2.0, metric=metric)
    pts, _labels, idx = _build(rng, cfg=cfg, d=8)
    store = quantize_index(idx, cfg)
    n = int(idx.points_sorted.shape[0])
    q = _corner_queries(rng, pts)
    q_grid = to_grid_coords(idx.proj, q, cfg.grid_size)
    starts, ends = window_spans(idx, cfg, q_grid)
    args = (store.q_points, store.row_scales, starts, ends, q, 6, n,
            cfg.row_cap)
    dk, ik = ops.csr_shortlist_q8(*args, metric=metric, d_chunk=d_chunk)
    dr, ir = ref.csr_shortlist_q8(*args, metric=metric, d_chunk=d_chunk)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
    # integer scoring: distances match exactly, not approximately
    ka, kb = np.asarray(dk), np.asarray(dr)
    np.testing.assert_array_equal(np.isinf(ka), np.isinf(kb))
    np.testing.assert_array_equal(ka[np.isfinite(ka)], kb[np.isfinite(kb)])


def test_q8_shortlist_rejects_bad_rerank_k(rng):
    pts, _labels, idx = _build(rng)
    store = quantize_index(idx, CFG)
    q = jnp.asarray(np.zeros((2, 2)), jnp.float32)
    q_grid = to_grid_coords(idx.proj, q, CFG.grid_size)
    starts, ends = window_spans(idx, CFG, q_grid)
    with pytest.raises(ValueError, match="rerank_k"):
        ops.csr_shortlist_q8(store.q_points, store.row_scales, starts, ends,
                             q, CFG.window * CFG.row_cap + 1, N, CFG.row_cap)


# ------------------------------------------- containment => bit parity ------


def _assert_lane_equal(a, b, lanes, msg):
    for field in api.SearchResult._fields:
        ga = np.asarray(getattr(a, field))[lanes]
        gb = np.asarray(getattr(b, field))[lanes]
        np.testing.assert_array_equal(ga, gb, err_msg=f"{msg}:{field}")


@pytest.mark.parametrize("metric", ["l2", "l1"])
@settings(max_examples=6, deadline=None)
@given(
    seed=hst.integers(0, 2**31 - 1),
    spread=hst.sampled_from([0.02, 0.3, 1.5]),
    d_chunk=hst.sampled_from([None, 3]),
)
def test_shortlist_containment_implies_bit_parity(metric, seed, spread,
                                                  d_chunk):
    """Grid corners + skewed densities, both metrics, chunked and not:
    wherever the int8 shortlist contains the exact top-k, the re-ranked
    pallas_q8 result is BIT-IDENTICAL to the exact `pallas` backend — and
    a full-window shortlist makes that every lane."""
    cfg = GridConfig(grid_size=64, tile=8, n_classes=3, window=8, row_cap=4,
                     r0=4, k_slack=2.0, metric=metric)
    rng = np.random.default_rng(seed)
    pts, _labels, idx = _build(rng, cfg=cfg, spread=spread)
    s = api.ActiveSearcher.from_index(idx, cfg)
    q = _corner_queries(rng, pts)

    exact_fused = s.with_plan(backend="pallas", d_chunk=d_chunk).search(q, K)

    # full-window shortlist: containment holds trivially on every lane
    full = s.with_plan(backend="pallas_q8", d_chunk=d_chunk,
                       rerank_k=cfg.window * cfg.row_cap).search(q, K)
    _assert_lane_equal(exact_fused, full, np.arange(B), "full-window")

    # default shortlist: identify covered lanes via the coarse stage and
    # require bit parity exactly there
    store = quantize_index(idx, cfg)
    rk = batched.resolve_rerank_k(cfg, K, None)
    _sld, sl = batched.q8_shortlist(idx, store, cfg, q, rk, d_chunk=d_chunk)
    ids_sorted = padded_csr(idx, cfg.row_cap)[3]
    sl_ids = np.where(np.asarray(sl) >= 0,
                      np.asarray(ids_sorted)[np.maximum(np.asarray(sl), 0)],
                      -2)
    want_ids = np.asarray(exact_fused.ids)
    covered = np.all(
        (want_ids[:, :, None] == sl_ids[:, None, :]).any(-1)
        | ~np.asarray(exact_fused.valid),
        axis=-1,
    )
    got = s.with_plan(backend="pallas_q8", d_chunk=d_chunk).search(q, K)
    _assert_lane_equal(exact_fused, got, np.nonzero(covered)[0], "covered")


# --------------------------------------------------------------- mutation ----


def test_insert_invariance_under_pallas_q8(rng):
    """build(P1).insert(P2) == build(P1 u P2) on the quantized backend."""
    pts = jnp.asarray(rng.normal(size=(400, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 3, size=400), jnp.int32)
    proj = identity_projection(pts)
    plan = api.ExecutionPlan(backend="pallas_q8")
    grown = api.ActiveSearcher.from_index(
        build_index(pts[:300], CFG, proj, labels=labels[:300]), CFG, plan
    ).insert(pts[300:], labels=labels[300:])
    rebuilt = api.ActiveSearcher.from_index(
        build_index(pts, CFG, proj, labels=labels), CFG, plan
    )
    q = jnp.asarray(rng.normal(size=(B, 2)), jnp.float32)
    a, b = grown.search(q, K), rebuilt.search(q, K)
    _assert_lane_equal(a, b, np.arange(B), "insert-invariance")
    np.testing.assert_array_equal(
        np.asarray(grown.classify(q, K)), np.asarray(rebuilt.classify(q, K))
    )


def test_quantized_snapshot_equals_requantized_rebuild(rng):
    """mutable.quantized_snapshot: the store derived after insert is
    bit-identical to quantizing a from-scratch rebuild (the invariant that
    makes the engine's per-handle memo safe)."""
    from repro.core import mutable as mut

    pts = jnp.asarray(rng.normal(size=(400, 2)), jnp.float32)
    proj = identity_projection(pts)
    state = mut.from_index(build_index(pts[:300], CFG, proj), CFG)
    state = mut.insert(state, CFG, pts[300:])
    index, store = mut.quantized_snapshot(state, CFG)
    rebuilt = build_index(pts, CFG, proj)
    want = quantize_index(rebuilt, CFG)
    np.testing.assert_array_equal(np.asarray(index.points_sorted),
                                  np.asarray(rebuilt.points_sorted))
    for fa, fb in zip(store, want):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


# ---------------------------------------------------------------- backend ----


def test_pallas_q8_backend_smoke(rng):
    """search/classify/count_at all work through the facade; paper mode is
    exact (delegates to the fused stage), and the registered capabilities
    match the design."""
    pts, _labels, idx = _build(rng)
    s = api.ActiveSearcher.from_index(idx, CFG).with_plan(backend="pallas_q8")
    q = _corner_queries(rng, pts)

    res = s.search(q, K)
    assert res.ids.shape == (B, K) and res.dists.dtype == jnp.float32
    assert s.classify(q, K).shape == (B,)
    counts = s.count_at(q, jnp.full((B,), 4, jnp.int32))
    assert counts.shape == (B, CFG.n_classes)

    p = api.ActiveSearcher.from_index(idx, CFG).with_plan(backend="pallas")
    for op in ("search", "classify"):
        a = getattr(s, op)(q, K, mode="paper")
        b = getattr(p, op)(q, K, mode="paper")
        if op == "search":
            _assert_lane_equal(a, b, np.arange(B), "paper")
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    impl = api.get_backend("pallas_q8")
    assert impl.supports_quantized and impl.supports_mutation
    assert impl.supports_interpret and impl.supports_d_chunk
    # chunked streaming is bit-identical, and the store memo survives it
    chunked = s.with_plan(backend="pallas_q8", chunk_size=3).search(q, K)
    _assert_lane_equal(res, chunked, np.arange(B), "chunked")
    assert s.__dict__.get("_quantized_store_cache") is not None
