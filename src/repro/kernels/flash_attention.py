"""Pallas TPU kernel: flash attention (online-softmax), causal or full.

The §Perf hillclimb on `musicgen-medium prefill_32k` showed the memory term
(4.4 s) dominated by (cq, S) score/prob buffers round-tripping HBM — 10 bytes
per score element per layer.  This kernel keeps the running max/denominator/
output accumulator in VMEM scratch across the sequential KV-block axis
(exactly the streaming-top-k pattern brute_knn uses), so HBM traffic drops to
q/k/v/o only.

Grid = (B*H, nq, nk) with the KV axis minormost (sequential on TPU) so the
scratch legally persists across kv steps.  Causal masking is by absolute
block position; fully-masked blocks still run (branchless) — acceptable at
<=2x and TPU-friendly.  MXU alignment: block_q/block_k default 512/512,
hd is the contraction dim.

Validated with interpret=True against ref.flash_attention (= plain softmax
attention) over shape/causal sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,    # (1, bq, hd) float32
    k_ref,    # (1, bk, hd) float32
    v_ref,    # (1, bk, hd) float32
    o_ref,    # (1, bq, hd) float32
    m_ref,    # scratch (bq,) float32 — running max
    l_ref,    # scratch (bq,) float32 — running denominator
    acc_ref,  # scratch (bq, hd) float32 — running numerator
    *,
    bq: int,
    bk: int,
    nk: int,
    causal: bool,
    scale: float,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # (bq, hd)
    k = k_ref[0]
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)

    if causal:
        i = pl.program_id(1)
        q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # guard: fully-masked rows keep m = -inf; exp(s - (-inf)) must be 0
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    alpha = jnp.where(m_prev == NEG_INF, 0.0, alpha)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(m_new[:, None] == NEG_INF, 0.0, p)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,   # (B, S, H, hd)
    k: jax.Array,   # (B, T, H, hd) — pre-expanded GQA
    v: jax.Array,   # (B, T, H, hd)
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Contract identical to ref.flash_attention."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, t)
    nq = -(-s // bq)
    nk = -(-t // bk)
    if nq * bq != s or nk * bk != t:
        raise ValueError(f"seq {s}/{t} must divide blocks {bq}/{bk}")

    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd).astype(jnp.float32)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, t, hd).astype(jnp.float32)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, t, hd).astype(jnp.float32)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, nk=nk, causal=causal, scale=1.0 / (hd ** 0.5)
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return jnp.moveaxis(out.reshape(b, h, s, hd), 1, 2)
