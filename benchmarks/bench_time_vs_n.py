"""Paper Fig. 3: elapsed time vs N — original kNN grows with N, active search
is ~independent of N (the paper's headline claim).

100 query points, k=11, 3 classes.  Grid fixed while N varies, exactly as the
paper fixes its 3000x3000 image.  (grid_size is CPU-scaled; the 3000-image
setting runs in bench_accuracy.py.)

Both sides run through ONE ActiveSearcher handle: the exact comparator is
the registered "exact" backend, and the active-search plan (backend /
chunk_size) is constructed once from the CLI and re-used for every N.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, paper_data, timeit
from repro.api import ActiveSearcher, ExecutionPlan, GridConfig, identity_projection

K = 11
N_QUERIES = 100


def main(
    grid_size: int = 1024,
    ns=(1_000, 4_000, 16_000, 64_000, 256_000),
    plan: ExecutionPlan | None = None,
) -> None:
    """plan selects the execution path once — e.g.
    ExecutionPlan(backend="pallas") times the batched kernel pipeline
    (interpret-mode on CPU — compare on TPU for hardware numbers) and
    chunk_size streams queries through fixed-size kernel invocations."""
    plan = plan or ExecutionPlan()
    rng = np.random.default_rng(0)
    csv = Csv("n,backend,exact_knn_s,active_search_s,active_build_s,speedup")
    cfg = GridConfig(grid_size=grid_size, tile=16, n_classes=3, window=64,
                     row_cap=64, r0=100, k_slack=2.0)
    q, _ = paper_data(rng, N_QUERIES)

    for n in ns:
        pts, labels = paper_data(rng, n)
        proj = identity_projection(pts)
        build = lambda: ActiveSearcher.build(
            pts, labels=labels, cfg=cfg, plan=plan, proj=proj
        )
        t_build = timeit(build, repeats=3, warmup=1)
        searcher = build()
        brute = searcher.with_plan(backend="exact")
        t_exact = timeit(lambda: brute.classify(q, K), repeats=3)
        t_act = timeit(lambda: searcher.classify(q, K), repeats=3)
        csv.row(n, plan.backend, f"{t_exact:.4f}", f"{t_act:.4f}",
                f"{t_build:.4f}", f"{t_exact / t_act:.2f}")

    # derived: paper claims active-search time ~independent of N
    return csv


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="jnp",
                    help="registered backend name (repro.api)")
    ap.add_argument("--grid-size", type=int, default=1024)
    ap.add_argument("--chunk-size", type=int, default=None)
    args = ap.parse_args()
    main(grid_size=args.grid_size,
         plan=ExecutionPlan(backend=args.backend, chunk_size=args.chunk_size))
