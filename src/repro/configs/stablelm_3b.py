"""stablelm-3b [dense] — (hf:stabilityai/stablelm family; unverified).

32L d_model=2560 32H (GQA kv=32 = full MHA) d_ff=6912 vocab=50304.
long_500k: SKIP (pure full attention)."""

from repro.models.config import ModelConfig, ParallelismPolicy

LONG_CONTEXT = "skip"

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    head_dim=80,
    policy=ParallelismPolicy(remat="full", scan_layers=True, accum=4),
)

SMOKE = ModelConfig(
    name="stablelm-3b-smoke",
    family="dense",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=8,
    d_ff=256,
    vocab_size=512,
)
