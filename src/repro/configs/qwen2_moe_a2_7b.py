"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4, fine-grained experts
(hf:Qwen/Qwen1.5-MoE-A2.7B; hf).

24L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=151936.
Experts padded 60 -> 64 for model-axis divisibility (router never picks the
pad; DESIGN.md §4).  Shared experts = one fused MLP of 4*1408 = 5632.
long_500k: SKIP (pure full attention)."""

from repro.models.config import ModelConfig, MoEConfig, ParallelismPolicy

LONG_CONTEXT = "skip"

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    head_dim=128,
    moe=MoEConfig(
        n_experts=60,
        n_padded=4,
        top_k=4,
        d_expert=1408,
        shared_d_ff=5632,
        group_size=512,
    ),
    moe_layers=(True,),
    policy=ParallelismPolicy(remat="full", scan_layers=True, accum=4),
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=8,
    d_ff=64,
    vocab_size=512,
    # capacity_factor 4: drop-free at smoke scale (prefill/decode consistency)
    moe=MoEConfig(n_experts=6, n_padded=2, top_k=4, d_expert=64, shared_d_ff=256,
                  group_size=64, capacity_factor=4.0),
    moe_layers=(True,),
)
