"""Sharded active-search index: query cost independent of N *per shard*.

Cluster-scale layout (DESIGN.md §2): the datastore of N points is sharded
along a mesh axis; every shard builds its OWN grid over the SAME global
extents, with GLOBAL point ids.  A query (replicated) runs active search on
all shards in parallel under shard_map, then the per-shard top-k lists
(k * n_shards values — small) are merged with one all_gather + top_k.

Per-shard query cost stays N-independent (the paper's property); the merge is
O(k * n_shards), independent of N.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.active_search import SearchResult
from repro.core.grid import GridConfig, GridIndex, build_index
from repro.core.projection import Projection


def build_sharded_index(
    points: jax.Array,
    cfg: GridConfig,
    proj: Projection,
    mesh: Mesh,
    axis: str,
    labels: jax.Array | None = None,
) -> GridIndex:
    """Build one grid index per `axis` shard.

    Returns a GridIndex whose array leaves carry a leading shard dimension of
    size mesh.shape[axis], sharded along `axis`.  N must divide evenly.
    """
    n_shards = mesh.shape[axis]
    n = points.shape[0]
    if n % n_shards:
        raise ValueError(f"N={n} must divide n_shards={n_shards}")
    n_local = n // n_shards

    if labels is None:
        labels = jnp.zeros((n,), dtype=jnp.int32)

    def local_build(pts, lab):
        # leading shard dim is 1 inside shard_map
        shard = lax.axis_index(axis)
        gids = shard * n_local + jnp.arange(n_local, dtype=jnp.int32)
        idx = build_index(pts[0], cfg, proj, labels=lab[0], ids=gids)
        return jax.tree.map(lambda a: a[None], idx)

    pts_s = points.reshape(n_shards, n_local, -1)
    lab_s = labels.reshape(n_shards, n_local)
    fn = shard_map(
        local_build,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
        check_rep=False,
    )
    return fn(pts_s, lab_s)


@partial(
    jax.jit,
    static_argnames=("cfg", "k", "mode", "axis", "mesh", "adaptive_r0"),
)
def sharded_search(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mesh: Mesh,
    axis: str,
    mode: str = "refined",
    adaptive_r0: bool = False,
) -> SearchResult:
    """Active search over the sharded index; queries (B, d) replicated.

    Registered as backend "sharded" in the engine registry (core/engine.py):
    every shard runs its OWN per-shard ActiveSearcher handle (jnp plan) under
    shard_map, then the per-shard top-k lists are merged.  Returns the
    globally merged top-k per query (ids are global point ids).
    `adaptive_r0` seeds each shard's Eq.-1 loop from that shard's OWN
    pyramid (density differs per shard, so seeds do too — exactly like every
    other per-shard Eq.-1 quantity).
    """
    # function-level import: engine registers this module's search as a
    # backend, so a top-level import would be circular
    from repro.core import engine as eng

    local_plan = eng.ExecutionPlan(backend="jnp", adaptive_r0=adaptive_r0)

    def local_query(idx_stacked, q):
        idx = jax.tree.map(lambda a: a[0], idx_stacked)
        shard = eng.ActiveSearcher(index=idx, cfg=cfg, plan=local_plan)
        res = shard.search(q, k, mode=mode)                  # (B, k) per-shard
        d_all = lax.all_gather(res.dists, axis)               # (S, B, k)
        i_all = lax.all_gather(res.ids, axis)
        l_all = lax.all_gather(res.labels, axis)
        b = q.shape[0]
        d_flat = jnp.moveaxis(d_all, 0, 1).reshape(b, -1)     # (B, S*k)
        i_flat = jnp.moveaxis(i_all, 0, 1).reshape(b, -1)
        l_flat = jnp.moveaxis(l_all, 0, 1).reshape(b, -1)
        neg, sel = lax.top_k(-d_flat, k)
        top_d = -neg
        ok = jnp.isfinite(top_d)
        merged = SearchResult(
            ids=jnp.where(ok, jnp.take_along_axis(i_flat, sel, axis=1), -1),
            dists=top_d,
            labels=jnp.where(ok, jnp.take_along_axis(l_flat, sel, axis=1), -1),
            valid=ok,
            # diagnostics: reduce across shards
            radius=lax.pmax(res.radius, axis),
            count=lax.psum(res.count, axis),
            iters=lax.pmax(res.iters, axis),
            converged=jnp.logical_and(
                lax.pmin(res.converged.astype(jnp.int32), axis) > 0, True
            ),
            truncated=lax.pmax(res.truncated.astype(jnp.int32), axis) > 0,
        )
        return merged

    in_specs = (P(axis), P())
    out_specs = P()
    fn = shard_map(
        local_query, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
    return fn(index, queries)


def replicate_queries(queries: jax.Array, mesh: Mesh) -> jax.Array:
    return jax.device_put(queries, NamedSharding(mesh, P()))
