"""The ActiveSearcher facade (core/engine.py, exported as repro.api):
backend registry, ExecutionPlan validation, parity with the pre-facade
entry points, deprecation shims, and the B=0 run_chunked regression."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import active_search as act
from repro.core import exact
from repro.core.active_search import run_chunked
from repro.core.grid import GridConfig, build_index
from repro.core.projection import identity_projection


def _searcher(rng, n=1000, n_classes=3, **kw):
    pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, max(n_classes, 1), size=n), jnp.int32)
    cfg = GridConfig(grid_size=128, tile=16, n_classes=n_classes, window=48,
                     row_cap=48, r0=8, k_slack=2.0, **kw)
    idx = build_index(pts, cfg, identity_projection(pts), labels=labels)
    return pts, labels, api.ActiveSearcher.from_index(idx, cfg)


def _assert_results_equal(a, b):
    for field in api.SearchResult._fields:
        ga, gb = np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
        assert ga.shape == gb.shape, (field, ga.shape, gb.shape)
        assert ga.dtype == gb.dtype, (field, ga.dtype, gb.dtype)
        np.testing.assert_array_equal(ga, gb, err_msg=field)


# ------------------------------------------------------------------ parity ---


@pytest.mark.parametrize("mode", ["refined", "paper"])
def test_facade_parity_jnp_vs_pallas(rng, mode):
    """The handle is bit-identical to the pre-facade paths: the jnp plan
    reproduces _search_jnp, the pallas plan reproduces core.batched, and the
    two plans agree with each other — search AND classify, both modes."""
    _, _, s = _searcher(rng)
    q = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)
    ref = act._search_jnp(s.index, s.cfg, q, 8, mode)
    got = s.search(q, 8, mode=mode)
    _assert_results_equal(ref, got)
    got_p = s.with_plan(backend="pallas").search(q, 8, mode=mode)
    _assert_results_equal(ref, got_p)
    np.testing.assert_array_equal(
        np.asarray(s.classify(q, 8, mode=mode)),
        np.asarray(s.with_plan(backend="pallas").classify(q, 8, mode=mode)),
    )


def test_facade_parity_exact(rng):
    """The exact backend folds ExactResult into SearchResult: same ids and
    distances as the raw comparator (original point order), paper-stat
    fields defaulted, classify bit-identical to exact.classify."""
    pts, labels, s = _searcher(rng)
    q = jnp.asarray(rng.normal(size=(6, 2)), jnp.float32)
    raw = exact.knn(q, pts, 8, metric=s.cfg.metric)
    got = s.with_plan(backend="exact").search(q, 8)
    np.testing.assert_array_equal(np.asarray(raw.ids), np.asarray(got.ids))
    np.testing.assert_array_equal(np.asarray(raw.dists), np.asarray(got.dists))
    assert got.labels.shape == got.ids.shape
    np.testing.assert_array_equal(
        np.asarray(got.labels),
        np.asarray(labels)[np.asarray(raw.ids)],
    )
    # paper-stat fields are defaulted, batched, and well-typed
    assert got.radius.shape == (6,) and int(np.asarray(got.radius).max()) == 0
    assert bool(np.asarray(got.converged).all())
    assert not bool(np.asarray(got.truncated).any())
    np.testing.assert_array_equal(
        np.asarray(exact.classify(q, pts, labels, 8, 3)),
        np.asarray(s.with_plan(backend="exact").classify(q, 8)),
    )


def test_count_at_parity_across_backends(rng):
    """count_at: jnp (vmap count_in_circle) == pallas (level-scheduled
    kernel) == pallas_stacked (PR-1 baseline) for radii spanning levels."""
    _, _, s = _searcher(rng)
    q = jnp.asarray(rng.normal(size=(10, 2)), jnp.float32)
    radii = jnp.asarray(rng.integers(1, s.cfg.max_radius, size=10), jnp.int32)
    want = s.count_at(q, radii)
    for backend in ("pallas", "pallas_stacked"):
        got = s.with_plan(backend=backend).count_at(q, radii)
        np.testing.assert_array_equal(
            np.asarray(want), np.asarray(got), err_msg=backend
        )


# ---------------------------------------------------------------- registry ---


def test_unknown_backend_lists_registered_names(rng):
    _, _, s = _searcher(rng)
    q = jnp.zeros((1, 2), jnp.float32)
    with pytest.raises(ValueError, match=r"unknown backend 'tpu-magic'"):
        s.with_plan(backend="tpu-magic").search(q, 3)
    with pytest.raises(ValueError, match=r"'jnp'.*'pallas'"):
        s.with_plan(backend="tpu-magic").search(q, 3)


def test_register_backend_roundtrip(rng):
    """A custom BackendImpl registered under a new name is dispatched by the
    facade with the searcher handle and the call arguments intact."""
    _, _, s = _searcher(rng)
    q = jnp.zeros((2, 2), jnp.float32)
    seen = {}

    def fake_search(searcher, queries, k, mode):
        seen["cfg"] = searcher.cfg
        seen["k"], seen["mode"] = k, mode
        return act._search_jnp(searcher.index, searcher.cfg, queries, k, mode)

    api.register_backend("custom-test", api.BackendImpl(search=fake_search))
    try:
        assert "custom-test" in api.registered_backends()
        got = s.with_plan(backend="custom-test").search(q, 3, mode="paper")
        assert seen == {"cfg": s.cfg, "k": 3, "mode": "paper"}
        _assert_results_equal(act._search_jnp(s.index, s.cfg, q, 3, "paper"), got)
        # ops the impl does not provide raise eagerly, naming the backend
        with pytest.raises(ValueError, match="custom-test.*classify"):
            s.with_plan(backend="custom-test").classify(q, 3)
    finally:
        from repro.core import engine

        engine._REGISTRY.pop("custom-test", None)
    with pytest.raises(TypeError, match="BackendImpl"):
        api.register_backend("bad", lambda *a: None)


# ------------------------------------------------------------------- shims ---


def test_deprecated_shims_warn_and_match_facade(rng):
    _, _, s = _searcher(rng)
    q = jnp.asarray(rng.normal(size=(4, 2)), jnp.float32)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        shim_res = act.search(s.index, s.cfg, q, 5, backend="pallas")
        shim_cls = act.classify(s.index, s.cfg, q, 5)
    assert sum(issubclass(x.category, DeprecationWarning) for x in w) == 2
    _assert_results_equal(s.with_plan(backend="pallas").search(q, 5), shim_res)
    np.testing.assert_array_equal(
        np.asarray(s.classify(q, 5)), np.asarray(shim_cls)
    )


# -------------------------------------------------------- eager validation ---


@pytest.mark.parametrize("backend", ["jnp", "pallas", "exact"])
def test_classify_without_classes_raises_uniformly(rng, backend):
    _, _, s = _searcher(rng, n=300, n_classes=0)
    q = jnp.zeros((2, 2), jnp.float32)
    with pytest.raises(ValueError, match="n_classes > 0"):
        s.with_plan(backend=backend).classify(q, 3)


@pytest.mark.parametrize("backend", ["jnp", "exact", "sharded"])
def test_interpret_rejected_uniformly_off_pallas(rng, backend):
    _, _, s = _searcher(rng, n=300)
    q = jnp.zeros((2, 2), jnp.float32)
    with pytest.raises(ValueError, match="interpret"):
        s.with_plan(backend=backend, interpret=True).search(q, 3)
    with pytest.raises(ValueError, match="interpret"):
        s.with_plan(backend=backend, interpret=False).classify(q, 3)


def test_plan_validation(rng):
    with pytest.raises(ValueError, match="chunk_size"):
        api.ExecutionPlan(chunk_size=0)
    with pytest.raises(ValueError, match="donate"):
        api.ExecutionPlan(donate=True)
    _, _, s = _searcher(rng, n=200)
    with pytest.raises(ValueError, match="mode"):
        s.search(jnp.zeros((1, 2), jnp.float32), 3, mode="telepathic")
    with pytest.raises(ValueError, match="full ExecutionPlan OR"):
        s.with_plan(api.ExecutionPlan(), backend="pallas")


def test_gridconfig_rejects_unknown_metric():
    with pytest.raises(ValueError, match="metric"):
        GridConfig(metric="cosine")
    with pytest.raises(ValueError, match="counter"):
        GridConfig(counter="hyperloglog")
    GridConfig(metric="l1")  # both paper metrics still construct
    GridConfig(metric="l2")


# -------------------------------------------------------------- B=0 batches --


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_empty_batch_with_chunking(rng, backend):
    """Regression: B=0 with chunk_size set must return empty, correctly
    shaped pytrees instead of tripping the pad-by-last-row broadcast or
    invoking a kernel on a zero-size grid."""
    _, _, s = _searcher(rng, n=300)
    s = s.with_plan(backend=backend, chunk_size=4)
    empty = jnp.zeros((0, 2), jnp.float32)
    res = s.search(empty, 5)
    assert res.ids.shape == (0, 5) and res.ids.dtype == jnp.int32
    assert res.dists.shape == (0, 5) and res.dists.dtype == jnp.float32
    assert res.radius.shape == (0,) and res.valid.dtype == bool
    cls = s.classify(empty, 5)
    assert cls.shape == (0,) and cls.dtype == jnp.int32


def test_run_chunked_empty_direct():
    out = run_chunked(
        lambda q: {"x": q * 2.0, "n": jnp.sum(q, axis=1)},
        jnp.zeros((0, 3), jnp.float32),
        chunk_size=8,
    )
    assert out["x"].shape == (0, 3) and out["n"].shape == (0,)


# ------------------------------------------------------------------- misc ----


def test_with_plan_and_stats(rng):
    _, _, s = _searcher(rng, n=400)
    s2 = s.with_plan(backend="pallas", chunk_size=16)
    assert s2.plan == api.ExecutionPlan(backend="pallas", chunk_size=16)
    assert s2.index is s.index and s.plan.backend == "jnp"  # original untouched
    st = s2.stats()
    assert st["n_points"] == 400 and st["backend"] == "pallas"
    assert st["csr_bytes"] > 0 and st["pyr_tiles_bytes"] > 0
    assert st["levels"] == s.cfg.levels


def test_build_defaults_to_pca_projection(rng):
    pts = jnp.asarray(rng.normal(size=(500, 8)), jnp.float32)
    s = api.ActiveSearcher.build(pts, cfg=GridConfig(grid_size=128, tile=16,
                                                     window=32, row_cap=32,
                                                     r0=8, k_slack=2.0))
    q = pts[:4]
    res = s.search(q, 5)
    assert res.ids.shape == (4, 5)
    # a stored point must find itself as its own nearest neighbor
    np.testing.assert_array_equal(np.asarray(res.ids[:, 0]), np.arange(4))


def test_chunked_facade_parity(rng):
    _, _, s = _searcher(rng, n=600)
    q = jnp.asarray(rng.normal(size=(10, 2)), jnp.float32)
    full = s.search(q, 5)
    chunked = s.with_plan(chunk_size=3).search(q, 5)
    _assert_results_equal(full, chunked)


def test_count_at_respects_chunking_and_empty(rng):
    """count_at streams (q_grid, radius) PAIRS through plan.chunk_size —
    bit-identical to the unchunked call — and returns an empty (0, C)
    result for an empty batch instead of reaching a kernel."""
    _, _, s = _searcher(rng, n=500)
    q = jnp.asarray(rng.normal(size=(7, 2)), jnp.float32)
    radii = jnp.asarray(rng.integers(1, s.cfg.max_radius, size=7), jnp.int32)
    full = s.count_at(q, radii)
    chunked = s.with_plan(chunk_size=3).count_at(q, radii)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(chunked))
    empty = s.with_plan(chunk_size=3).count_at(
        jnp.zeros((0, 2), jnp.float32), jnp.zeros((0,), jnp.int32)
    )
    assert empty.shape == (0, s.cfg.n_channels)


def test_exact_ordered_cached_on_handle(rng):
    """The exact backend's restored-order arrays are computed once per
    handle, not once per call."""
    _, _, s = _searcher(rng, n=400)
    e = s.with_plan(backend="exact")
    q = jnp.asarray(rng.normal(size=(3, 2)), jnp.float32)
    first = e.search(q, 4)
    cache = e.__dict__.get("_exact_ordered_cache")
    assert cache is not None
    second = e.search(q, 4)
    assert e.__dict__["_exact_ordered_cache"] is cache  # reused, not rebuilt
    _assert_results_equal(first, second)


def test_exact_cache_does_not_leak_tracers(rng):
    """Regression: memoizing the reorder while tracing (closed-over handle
    under jit, or the B=0 eval_shape probe) must not store tracers on the
    handle — later calls would die with UnexpectedTracerError."""
    _, _, s = _searcher(rng, n=300)
    e = s.with_plan(backend="exact")
    f = jax.jit(lambda q: e.search(q, 4).ids)
    assert f(jnp.zeros((3, 2), jnp.float32)).shape == (3, 4)
    assert f(jnp.zeros((7, 2), jnp.float32)).shape == (7, 4)  # retrace, reuse handle
    e2 = s.with_plan(backend="exact", chunk_size=4)
    e2.search(jnp.zeros((0, 2), jnp.float32), 4)  # eval_shape probe path
    res = e2.search(jnp.zeros((2, 2), jnp.float32), 4)  # must not crash
    assert res.ids.shape == (2, 4)


def test_with_plan_backend_switch_drops_interpret(rng):
    """Switching backends via with_plan clears the Pallas-only interpret
    knob instead of tripping validation (explicit interpret= still wins)."""
    _, _, s = _searcher(rng, n=300)
    p = s.with_plan(backend="pallas", interpret=True)
    q = jnp.asarray(rng.normal(size=(2, 2)), jnp.float32)
    res = p.with_plan(backend="exact").search(q, 3)  # must not raise
    assert res.ids.shape == (2, 3)
    assert p.with_plan(backend="jnp").plan.interpret is None
    assert p.with_plan(backend="pallas_stacked").plan.interpret is True
    with pytest.raises(ValueError, match="interpret"):
        p.with_plan(backend="exact", interpret=True).search(q, 3)


def test_d_chunk_plan_validation(rng):
    """d_chunk is eager and uniform like interpret: positive-only at plan
    construction, Pallas candidate-ranking backends only at dispatch, and
    with_plan backend switches drop the now-illegal knob."""
    _, _, s = _searcher(rng, n=300)
    q = jnp.asarray(rng.normal(size=(2, 2)), jnp.float32)
    for bad in (0, -4):
        with pytest.raises(ValueError, match="d_chunk"):
            api.ExecutionPlan(d_chunk=bad)
    for backend in ("jnp", "exact"):
        with pytest.raises(ValueError, match="d_chunk"):
            s.with_plan(backend=backend, d_chunk=8).search(q, 3)
    # count-only pallas_stacked never ranks candidates either
    with pytest.raises(ValueError, match="d_chunk"):
        s.with_plan(backend="pallas_stacked", d_chunk=8).count_at(
            q, jnp.ones((2,), jnp.int32)
        )
    p = s.with_plan(backend="pallas", d_chunk=8)
    assert p.search(q, 3).ids.shape == (2, 3)
    assert p.with_plan(backend="exact").plan.d_chunk is None  # dropped
    assert p.with_plan(backend="pallas_gather").plan.d_chunk == 8  # kept


def test_adaptive_r0_plan_validation(rng):
    """adaptive_r0 is gated like interpret/d_chunk: only backends that run
    the Eq.-1 radius loop accept it, with_plan backend switches drop the
    now-illegal knob, and an explicit override still wins."""
    _, _, s = _searcher(rng, n=300)
    q = jnp.asarray(rng.normal(size=(2, 2)), jnp.float32)
    for backend in ("exact", "pallas_stacked"):
        assert not api.get_backend(backend).supports_adaptive_r0
        with pytest.raises(ValueError, match="adaptive_r0"):
            s.with_plan(backend=backend, adaptive_r0=True)._impl("search")
    for backend in ("jnp", "pallas", "pallas_gather", "sharded"):
        assert api.get_backend(backend).supports_adaptive_r0, backend
    p = s.with_plan(backend="pallas", adaptive_r0=True)
    assert p.search(q, 3).ids.shape == (2, 3)
    assert p.with_plan(backend="exact").plan.adaptive_r0 is False  # dropped
    assert p.with_plan(backend="jnp").plan.adaptive_r0 is True     # kept
    with pytest.raises(ValueError, match="adaptive_r0"):
        p.with_plan(backend="exact", adaptive_r0=True).search(q, 3)


def test_rerank_k_plan_validation(rng):
    """rerank_k is gated like d_chunk: positive-only at plan construction,
    quantized-candidate backends only at dispatch (supports_quantized),
    rerank_k >= k at the search call where k is known, and with_plan
    backend switches drop the now-illegal knob."""
    _, _, s = _searcher(rng, n=300)
    q = jnp.asarray(rng.normal(size=(2, 2)), jnp.float32)
    for bad in (0, -4):
        with pytest.raises(ValueError, match="rerank_k"):
            api.ExecutionPlan(rerank_k=bad)
    for backend in ("jnp", "pallas", "pallas_gather", "exact"):
        assert not api.get_backend(backend).supports_quantized
        with pytest.raises(ValueError, match="rerank_k"):
            s.with_plan(backend=backend, rerank_k=8).search(q, 3)
    assert api.get_backend("pallas_q8").supports_quantized
    # a shortlist shallower than k can never return k exact rows
    with pytest.raises(ValueError, match="rerank_k"):
        s.with_plan(backend="pallas_q8", rerank_k=2).search(q, 3)
    p = s.with_plan(backend="pallas_q8", rerank_k=8)
    assert p.search(q, 3).ids.shape == (2, 3)
    assert p.with_plan(backend="pallas").plan.rerank_k is None  # dropped
    assert p.with_plan(backend="pallas_q8", chunk_size=2).plan.rerank_k == 8


@pytest.mark.parametrize("mode", ["refined", "paper"])
def test_adaptive_r0_parity_across_backends(rng, mode):
    """ISSUE-6 acceptance: with adaptive_r0=True every registered backend
    returns the SAME SearchResult as the jnp oracle — ids/dists AND the
    Eq.-1 stat fields (radius/count/iters/converged), both modes."""
    _, _, s = _searcher(rng)
    q = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)
    ref = act._search_jnp(s.index, s.cfg, q, 8, mode, adaptive_r0=True)
    for backend in ("jnp", "pallas", "pallas_gather"):
        got = s.with_plan(backend=backend, adaptive_r0=True).search(
            q, 8, mode=mode
        )
        _assert_results_equal(ref, got)
        np.testing.assert_array_equal(
            np.asarray(s.with_plan(adaptive_r0=True).classify(q, 8, mode=mode)),
            np.asarray(s.with_plan(backend=backend, adaptive_r0=True)
                       .classify(q, 8, mode=mode)),
            err_msg=backend,
        )


def test_pallas_gather_registered_and_bit_identical(rng):
    """The gather pipeline survives as a full registered backend (search,
    classify, count_at) and matches the fused default bit-for-bit."""
    assert "pallas_gather" in api.registered_backends()
    impl = api.get_backend("pallas_gather")
    assert impl.supports_interpret and impl.supports_d_chunk
    _, _, s = _searcher(rng, n=800)
    q = jnp.asarray(rng.normal(size=(6, 2)), jnp.float32)
    fused = s.with_plan(backend="pallas")
    gather = s.with_plan(backend="pallas_gather")
    _assert_results_equal(fused.search(q, 7), gather.search(q, 7))
    np.testing.assert_array_equal(
        np.asarray(fused.classify(q, 7)), np.asarray(gather.classify(q, 7))
    )
    radii = jnp.full((6,), 5, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(fused.count_at(q, radii)),
        np.asarray(gather.count_at(q, radii)),
    )


def test_from_index_upgrades_pre_layout_tiles(rng):
    """A pre-layout index (pyr_tiles=None) is upgraded ONCE by from_index;
    the pallas count path refuses to re-flatten per call."""
    from repro.core import batched
    from repro.core.grid import flatten_pyramid_tiles

    pts, labels, s = _searcher(rng, n=400)
    stripped = s.index._replace(pyr_tiles=None)
    up = api.ActiveSearcher.from_index(stripped, s.cfg)
    assert up.index.pyr_tiles is not None
    np.testing.assert_array_equal(
        np.asarray(up.index.pyr_tiles),
        np.asarray(flatten_pyramid_tiles(stripped.pyramid, s.cfg.tile)),
    )
    q = jnp.asarray(rng.normal(size=(2, 2)), jnp.float32)
    _assert_results_equal(
        up.with_plan(backend="pallas").search(q, 3),
        s.with_plan(backend="pallas").search(q, 3),
    )
    # reaching the kernels with a pre-layout index is a hard error now
    with pytest.raises(ValueError, match="pyr_tiles"):
        batched.batched_counts(
            stripped, s.cfg, jnp.zeros((1, 2), jnp.float32),
            jnp.ones((1,), jnp.int32),
        )


# ------------------------------------------------------ mutation capability --


def test_supports_mutation_capability_flags():
    """Every backend that can serve a refreshed post-mutation snapshot
    declares supports_mutation; the count-only baseline does not."""
    for name in ("jnp", "pallas", "pallas_gather", "exact", "sharded"):
        assert api.get_backend(name).supports_mutation, name
    assert not api.get_backend("pallas_stacked").supports_mutation


def test_serve_knn_online_rejects_non_mutation_backend(monkeypatch):
    """serve.py --knn-online validates by CAPABILITY before model init: a
    searchable backend without supports_mutation exits naming the flag and
    the capable alternatives — no name-matching, no late failure."""
    from repro.core import engine
    from repro.launch import serve

    api.register_backend(
        "searchonly-test",
        api.BackendImpl(search=lambda *a, **k: None),
    )
    try:
        monkeypatch.setattr(
            "sys.argv",
            ["serve", "--knn", "--knn-online",
             "--knn-backend", "searchonly-test"],
        )
        with pytest.raises(SystemExit, match="supports_mutation") as e:
            serve.main()
        assert "jnp" in str(e.value)  # the fix is in the message
    finally:
        engine._REGISTRY.pop("searchonly-test", None)
