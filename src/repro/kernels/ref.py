"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the exact contract of its kernel in ops.py; kernel tests
sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def tile_count(
    level_arr: jax.Array,   # (S, S, C) int32 — one pyramid level
    queries: jax.Array,     # (B, 2) float32 — positions in BASE-pixel units
    radii: jax.Array,       # (B,) float32 — radii in base-pixel units
    scale: int,             # 2**level
    tile: int,              # T — window side in level cells
    metric: str = "l2",
) -> jax.Array:
    """Circle-masked counts (B, C): count of points whose level-cell center
    lies within radius of the query.  Matches pyramid._count_at_level."""
    s = level_arr.shape[0]

    def one(q, r):
        cx = jnp.floor(q[0] / scale).astype(jnp.int32)
        cy = jnp.floor(q[1] / scale).astype(jnp.int32)
        ox = jnp.clip(cx - tile // 2, 0, s - tile)
        oy = jnp.clip(cy - tile // 2, 0, s - tile)
        window = lax.dynamic_slice(level_arr, (ox, oy, 0), (tile, tile, level_arr.shape[-1]))
        ci = (ox + jnp.arange(tile, dtype=jnp.float32) + 0.5) * scale
        cj = (oy + jnp.arange(tile, dtype=jnp.float32) + 0.5) * scale
        if metric == "l1":
            mask = (jnp.abs(ci - q[0])[:, None] + jnp.abs(cj - q[1])[None, :]) <= r
        else:
            d2 = (ci - q[0])[:, None] ** 2 + (cj - q[1])[None, :] ** 2
            mask = d2 <= r * r
        return jnp.sum(window * mask[:, :, None].astype(jnp.int32), axis=(0, 1))

    return jax.vmap(one)(queries.astype(jnp.float32), radii.astype(jnp.float32))


def tile_count_multilevel(
    pyramid: tuple[jax.Array, ...],  # level l: (S_l, S_l, C) int32
    queries: jax.Array,              # (B, 2) float32, base-pixel units
    radii: jax.Array,                # (B,) float32, base-pixel units
    levels: jax.Array,               # (B,) int32 pyramid level per query
    tile: int,
    metric: str = "l2",
) -> jax.Array:
    """Level-scheduled counts (B, C): each query counted at its OWN pyramid
    level — the stacked-select oracle for kernels.tile_count_multilevel."""
    per_level = jnp.stack(
        [
            tile_count(arr, queries, radii, 1 << lv, tile, metric=metric)
            for lv, arr in enumerate(pyramid)
        ],
        axis=0,
    )  # (L, B, C)
    lv = jnp.clip(levels.astype(jnp.int32), 0, len(pyramid) - 1)
    return jnp.take_along_axis(per_level, lv[None, :, None], axis=0)[0]


def candidate_topk(
    candidates: jax.Array,  # (B, C, d) float32
    valid: jax.Array,       # (B, C) bool
    queries: jax.Array,     # (B, d) float32
    k: int,
    metric: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """Top-k smallest distances among valid candidates.
    Returns dists (B, k) float32 (inf when <k valid) and idx (B, k) int32
    (candidate row index, -1 when invalid)."""
    diff = candidates - queries[:, None, :]
    if metric == "l1":
        d = jnp.sum(jnp.abs(diff), axis=-1)
    else:
        d = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    d = jnp.where(valid, d, jnp.inf)
    neg, idx = lax.top_k(-d, k)
    dists = -neg
    return dists, jnp.where(jnp.isfinite(dists), idx.astype(jnp.int32), -1)


def csr_candidate_topk(
    store: jax.Array,    # (n_pad, d) float32 — CSR-sorted ranking vectors
    starts: jax.Array,   # (B, w) int32 window-row span starts
    ends: jax.Array,     # (B, w) int32 window-row span ends
    queries: jax.Array,  # (B, d) float32
    k: int,
    n: int,              # live CSR rows
    row_cap: int,
    metric: str = "l2",
    radii: jax.Array | None = None,  # (B,) float32 paper-mode circle mask
    center_cells: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused-gather oracle: materialize the (B, w*row_cap) window the way
    gather_candidates_batched does, rank with candidate_topk's contract, and
    map the selected slots back to GLOBAL CSR row indices.
    Returns dists (B, k) float32 (inf pads) and idx (B, k) int32 (-1 pads)."""
    n_pad = store.shape[0]
    b, w = starts.shape
    s_cl = jnp.clip(starts, 0, max(n_pad - row_cap, 0))          # (B, w)
    j = s_cl[:, :, None] + jnp.arange(row_cap, dtype=jnp.int32)  # (B, w, cap)
    ok = (j >= starts[:, :, None]) & (j < ends[:, :, None]) & (j < n)
    flat = j.reshape(b, w * row_cap)
    cand = jnp.take(store, flat, axis=0)                 # (B, w*cap, d)
    if center_cells:
        cand = jnp.floor(cand) + 0.5
    diff = cand - queries[:, None, :].astype(jnp.float32)
    if metric == "l1":
        d = jnp.sum(jnp.abs(diff), axis=-1)
    else:
        d = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0))
    valid = ok.reshape(b, w * row_cap)
    if radii is not None:
        valid = valid & (d <= radii[:, None].astype(jnp.float32))
    d = jnp.where(valid, d, jnp.inf)
    k_eff = min(k, d.shape[1])
    neg, idx = lax.top_k(-d, k_eff)
    if k_eff < k:  # k exceeds the window: pad like the kernel does
        pad = k - k_eff
        neg = jnp.concatenate([neg, jnp.full((b, pad), -jnp.inf)], axis=1)
        idx = jnp.concatenate([idx, jnp.zeros((b, pad), idx.dtype)], axis=1)
    dists = -neg
    gidx = jnp.take_along_axis(flat, idx, axis=1)
    return dists, jnp.where(jnp.isfinite(dists), gidx, -1)


def csr_shortlist_q8(
    q_store: jax.Array,     # (n_pad, d) int8 — quantized CSR store
    row_scales: jax.Array,  # (n_pad, 1) float32 — per-row cell scales
    starts: jax.Array,      # (B, w) int32 window-row span starts
    ends: jax.Array,        # (B, w) int32 window-row span ends
    queries: jax.Array,     # (B, d) float32
    rerank_k: int,
    n: int,                 # live CSR rows
    row_cap: int,
    metric: str = "l2",
    d_chunk: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the int8 shortlist kernel (csr_candidate_topk_q8).

    The scoring is integer-deterministic, so this is an EXACT-match oracle
    (same clip/round/chunked accumulation as the kernel), not an allclose
    one.  Returns approx scores (B, rerank_k) float32 with +inf pads and
    GLOBAL CSR row indices (B, rerank_k) int32 with -1 pads, best-first.
    """
    from repro.kernels.csr_candidate_topk_q8 import QCLIP, q8_d_chunks

    n_pad, dim = q_store.shape
    b, w = starts.shape
    s_cl = jnp.clip(starts, 0, max(n_pad - row_cap, 0))          # (B, w)
    j = s_cl[:, :, None] + jnp.arange(row_cap, dtype=jnp.int32)  # (B, w, cap)
    ok = (j >= starts[:, :, None]) & (j < ends[:, :, None]) & (j < n)
    flat = j.reshape(b, w * row_cap)
    cand = jnp.take(q_store, flat, axis=0).astype(jnp.int32)  # (B, C, d)
    s = jnp.take(row_scales, flat, axis=0)                    # (B, C, 1)
    qs = jnp.clip(
        jnp.round(queries.astype(jnp.float32)[:, None, :] / s), -QCLIP, QCLIP
    ).astype(jnp.int32)
    diff = cand - qs
    chunks = q8_d_chunks(dim, d_chunk)
    if metric == "l1":
        acc = sum(
            jnp.sum(jnp.abs(diff[:, :, c0:c0 + dc]), axis=-1)
            for c0, dc in chunks
        )
        d = s[:, :, 0] * acc.astype(jnp.float32)
    else:
        acc = sum(
            jnp.sum(
                diff[:, :, c0:c0 + dc] * diff[:, :, c0:c0 + dc], axis=-1
            ).astype(jnp.float32)
            for c0, dc in chunks
        )
        d = s[:, :, 0] * jnp.sqrt(acc)
    d = jnp.where(ok.reshape(b, w * row_cap), d, jnp.inf)
    neg, idx = lax.top_k(-d, rerank_k)
    dists = -neg
    gidx = jnp.take_along_axis(flat, idx, axis=1)
    return dists, jnp.where(jnp.isfinite(dists), gidx, -1)


def brute_knn(
    queries: jax.Array,  # (B, d) float32
    points: jax.Array,   # (N, d) float32
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Exact L2 kNN.  Returns dists (B, k) ascending and ids (B, k) int32."""
    q = queries.astype(jnp.float32)
    x = points.astype(jnp.float32)
    d2 = (
        jnp.sum(q * q, axis=-1, keepdims=True)
        - 2.0 * (q @ x.T)
        + jnp.sum(x * x, axis=-1)[None, :]
    )
    d = jnp.sqrt(jnp.maximum(d2, 0.0))
    neg, idx = lax.top_k(-d, k)
    return -neg, idx.astype(jnp.int32)


def flash_attention(
    q: jax.Array,   # (B, S, H, hd)
    k: jax.Array,   # (B, T, H, hd)
    v: jax.Array,
    causal: bool = True,
) -> jax.Array:
    """Plain softmax attention — the flash_attention oracle."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s_ = jnp.einsum("bshk,bthk->bhst", qf, kf) / jnp.sqrt(q.shape[-1])
    if causal:
        sq, tk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(tk)[None, :]
        s_ = jnp.where(mask[None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bhst,bthk->bshk", p, vf).astype(q.dtype)
