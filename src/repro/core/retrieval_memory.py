"""Retrieval-augmented attention memory — beyond-paper long-context feature.

Memorizing-Transformers-style: at decode time a token attends to (a) a local
window of recent KV entries and (b) the top-m PAST positions retrieved by
active search over a grid index built on per-token key summaries.  Per-step
cost is O(local_window + m) instead of O(S): the paper's N-independent search
is exactly what makes 500k-token decode sub-quadratic for attention models
(DESIGN.md §5, beyond-paper extension).

The index key for a token is a summary of its attention keys (mean over KV
heads), projected to grid space; the query summary is the mean over query
heads.  Retrieval returns POSITIONS; the attention layer gathers their K/V.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.engine import ActiveSearcher, ExecutionPlan
from repro.core.grid import GridConfig, GridIndex, build_index
from repro.core.projection import Projection, gaussian_projection


@dataclasses.dataclass(frozen=True)
class RetrievalMemoryConfig:
    n_retrieved: int = 64     # m: positions fetched per decode step
    local_window: int = 512   # recent tokens attended exactly
    plan: ExecutionPlan = ExecutionPlan()  # HOW retrieval searches execute
    grid: GridConfig = dataclasses.field(
        default_factory=lambda: GridConfig(
            grid_size=2048, tile=16, window=32, row_cap=64, r0=8, k_slack=4.0,
            max_iters=12,
        )
    )


def key_summary(k_heads: jax.Array) -> jax.Array:
    """(S, n_kv, hd) -> (S, hd): the per-token index key."""
    return jnp.mean(k_heads.astype(jnp.float32), axis=-2)


def query_summary(q_heads: jax.Array) -> jax.Array:
    """(B, n_q, hd) -> (B, hd)."""
    return jnp.mean(q_heads.astype(jnp.float32), axis=-2)


def make_projection(key: jax.Array, head_dim: int) -> Projection:
    """Fixed random projection shared by keys and queries (data-independent,
    so the index can be extended without re-fitting extents)."""
    mat = jax.random.normal(key, (head_dim, 2), dtype=jnp.float32) / jnp.sqrt(head_dim)
    # attention keys are RMS-normed activations: |summary| is O(1); generous extents
    lo = jnp.full((2,), -4.0, jnp.float32)
    hi = jnp.full((2,), 4.0, jnp.float32)
    return Projection(mat, lo, hi)


def build_memory_index(
    keys: jax.Array, cfg: RetrievalMemoryConfig, proj: Projection
) -> GridIndex:
    """keys: (S, hd) per-token key summaries.  ids_sorted are positions."""
    return build_index(keys, cfg.grid, proj)


def extend_memory_index(
    index: GridIndex, cfg: RetrievalMemoryConfig, new_keys: jax.Array
) -> GridIndex:
    """Append (key, position) pairs ONLINE — the streaming-decode path.

    Positions continue from the current end of the memory (ids are the
    paper-side global point ids, which this module uses as token positions),
    and the grid/pyramid are delta-updated via `core.mutable` instead of
    rebuilt — `make_projection` is data-independent precisely so extents
    never need re-fitting.  Bit-identical to `build_memory_index` over the
    concatenated keys (tests/test_mutable.py).

    One-shot helper: re-opens the slack layout each call.  A decode loop
    appending every step should hold the `core.mutable.MutableIndex` (or an
    `ActiveSearcher` via `.insert`) across steps to reuse free slots."""
    from repro.core import mutable as mut

    state = mut.from_index(index, cfg.grid)
    return mut.snapshot(mut.insert(state, cfg.grid, new_keys), cfg.grid)


@partial(jax.jit, static_argnames=("cfg",))
def retrieve_positions(
    index: GridIndex, cfg: RetrievalMemoryConfig, q_sum: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """q_sum: (B, hd) -> positions (B, m) int32 and validity (B, m) bool."""
    searcher = ActiveSearcher.from_index(index, cfg.grid, plan=cfg.plan)
    res = searcher.search(q_sum, cfg.n_retrieved, mode="refined")
    return jnp.maximum(res.ids, 0), res.valid
