"""Deterministic synthetic LM data pipeline with per-host sharding + prefetch.

Production shape: every (step, host) pair maps to a disjoint, reproducible
slice of the token stream — restart-safe (resume at step k regenerates the
identical batch k) and elastic (re-sharding by host count changes only which
host holds which rows, never the global batch).  Tokens follow a Zipf-ish
bigram chain so the LM loss has learnable structure (tested).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.3
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2


def _host_rows(cfg: DataConfig) -> tuple[int, int]:
    assert cfg.global_batch % cfg.n_hosts == 0
    rows = cfg.global_batch // cfg.n_hosts
    return cfg.host_id * rows, rows


def synth_batch(cfg: DataConfig, step: int) -> dict:
    """The batch for `step`, host-local rows only.  Pure function of
    (seed, step, row) — the determinism contract the restart test checks.

    Token stream: a noisy affine Markov chain —
        x_{t+1} = (5 * x_t + 17 + eps_t) mod V,   eps ~ zipf-ish small noise
    — so the sequence HAS learnable transition structure: an LM learns the
    affine map, and a kNN-LM datastore memorizes exact continuations."""
    start, rows = _host_rows(cfg)
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    probs = 1.0 / np.arange(1, 17) ** cfg.zipf_a
    probs /= probs.sum()
    x = rng.integers(0, cfg.vocab_size, size=cfg.global_batch)
    eps = rng.choice(16, size=(cfg.global_batch, cfg.seq_len + 1), p=probs)
    cols = [x]
    for t in range(cfg.seq_len):
        x = (5 * x + 17 + eps[:, t]) % cfg.vocab_size
        cols.append(x)
    stream = np.stack(cols, axis=1)
    local = stream[start : start + rows]
    return {
        "tokens": local[:, :-1].astype(np.int32),
        "labels": local[:, 1:].astype(np.int32),
    }


def add_frontend_inputs(batch: dict, cfg: ModelConfig, step: int, seed: int = 0) -> dict:
    """Attach stub modality inputs (assignment: frontends are stubs)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 77]))
    b, s = batch["tokens"].shape
    if cfg.frontend == "audio":
        batch["frame_embeds"] = rng.normal(size=(b, s, cfg.d_model)).astype(np.float32)
    if cfg.frontend == "vision":
        batch["vision_embeds"] = rng.normal(
            size=(b, cfg.n_frontend_tokens, cfg.d_model)
        ).astype(np.float32)
    return batch


class Prefetcher:
    """Background-thread prefetch of host batches (overlap input with step)."""

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig | None = None, start_step: int = 0):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, step)
            if self.model_cfg is not None:
                batch = add_frontend_inputs(batch, self.model_cfg, step, self.cfg.seed)
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
