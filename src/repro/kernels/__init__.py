# Pallas TPU kernels for the paper's compute hot-spots (+ jnp oracles).
#   tile_count          — circle-masked pyramid-tile count (the paper's inner loop)
#   candidate_topk      — fused candidate distance + streaming top-k (dense input)
#   csr_candidate_topk  — fused CSR gather + distance + top-k straight from the
#                         sorted point store (no (B, w*row_cap, d) intermediate)
#   brute_knn           — blocked exact kNN baseline (streaming top-k on MXU)
# ops.py = jit'd wrappers (interpret=True on CPU), ref.py = pure-jnp oracles.

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
