"""Kernel microbench: the pure-JAX reference paths (what actually executes on
CPU) timed across sizes, plus one interpret-mode validation per Pallas kernel
(interpret=True timings are NOT hardware-meaningful — correctness only)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, timeit
from repro.kernels import ops, ref


def main() -> None:
    rng = np.random.default_rng(0)
    csv = Csv("kernel,config,ref_us_per_call,pallas_interpret_ok")

    # tile_count: one pyramid-level circle count
    for s, tile, c in ((256, 16, 1), (1024, 16, 4)):
        level = jnp.asarray(rng.integers(0, 4, size=(s, s, c)), jnp.int32)
        q = jnp.asarray(rng.uniform(0, s, size=(64, 2)), jnp.float32)
        r = jnp.asarray(rng.uniform(1, tile / 2 - 1.5, size=(64,)), jnp.float32)
        t = timeit(lambda: ref.tile_count(level, q, r, 1, tile), repeats=5)
        ok = bool(np.array_equal(
            np.asarray(ops.tile_count(level, q, r, 1, tile, interpret=True)),
            np.asarray(ref.tile_count(level, q, r, 1, tile)),
        ))
        csv.row("tile_count", f"S={s} T={tile} C={c} B=64", f"{t*1e6/64:.1f}", ok)

    # candidate_topk: post-gather re-rank
    for b, c, d, k in ((64, 256, 64, 16), (256, 1024, 128, 16)):
        cand = jnp.asarray(rng.normal(size=(b, c, d)), jnp.float32)
        valid = jnp.asarray(rng.uniform(size=(b, c)) > 0.2)
        q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
        t = timeit(lambda: ref.candidate_topk(cand, valid, q, k), repeats=5)
        gd, _ = ops.candidate_topk(cand[:4], valid[:4], q[:4], k, interpret=True)
        wd, _ = ref.candidate_topk(cand[:4], valid[:4], q[:4], k)
        ok = bool(np.allclose(np.asarray(gd), np.asarray(wd), atol=1e-4))
        csv.row("candidate_topk", f"B={b} C={c} d={d} k={k}", f"{t*1e6/b:.1f}", ok)

    # brute_knn: the paper's baseline
    for b, n, d, k in ((100, 10_000, 2, 11), (100, 100_000, 2, 11)):
        q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        t = timeit(lambda: ref.brute_knn(q, x, k), repeats=3)
        gd, _ = ops.brute_knn(q[:4], x[:2048], k, interpret=True)
        wd, _ = ref.brute_knn(q[:4], x[:2048], k)
        ok = bool(np.allclose(np.asarray(gd), np.asarray(wd), atol=1e-4))
        csv.row("brute_knn", f"B={b} N={n} d={d} k={k}", f"{t*1e6/b:.1f}", ok)

    bench_search_backends(rng, csv)
    return csv


def bench_search_backends(rng, csv: Csv) -> None:
    """End-to-end active search: per-query vmap path vs the batched
    kernel-backed pipeline (core/batched.py).  On CPU the pallas backend runs
    interpret-mode, so its ABSOLUTE time is not hardware-meaningful — the row
    pairs exist so the same sweep on a TPU (REPRO_PALLAS_INTERPRET=0) reads
    out the real speedup; the end-of-row flag re-checks result parity."""
    from repro.core import active_search as act
    from repro.core.grid import GridConfig, build_index
    from repro.core.projection import identity_projection

    k = 11
    cfg = GridConfig(grid_size=256, tile=16, n_classes=3, window=32,
                     row_cap=32, r0=10, k_slack=2.0)
    for n, b in ((20_000, 64), (100_000, 256)):
        pts = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 3, size=n), jnp.int32)
        idx = build_index(pts, cfg, identity_projection(pts), labels=labels)
        q = jnp.asarray(rng.normal(size=(b, 2)), jnp.float32)
        t_vmap = timeit(
            lambda: act.search(idx, cfg, q, k, backend="jnp").ids, repeats=3
        )
        t_pal = timeit(
            lambda: act.search(idx, cfg, q, k, backend="pallas").ids,
            repeats=3, warmup=1,
        )
        a = act.search(idx, cfg, q, k, backend="jnp")
        p = act.search(idx, cfg, q, k, backend="pallas")
        ok = bool(np.array_equal(np.asarray(a.ids), np.asarray(p.ids)))
        csv.row("search_vmap_jnp", f"N={n} B={b} k={k}", f"{t_vmap*1e6/b:.1f}", ok)
        csv.row("search_batched_pallas", f"N={n} B={b} k={k}", f"{t_pal*1e6/b:.1f}", ok)


if __name__ == "__main__":
    main()
