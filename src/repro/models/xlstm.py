"""xLSTM blocks: mLSTM (chunkwise-parallel matrix memory) + sLSTM (scan).

TPU adaptation notes (DESIGN.md §2/§5):
 * mLSTM trains with the chunkwise-parallel linear-attention form: an outer
   lax.scan carries the (B, nh, hd, hd) matrix memory across chunks; within a
   chunk the decay-weighted attention runs as dense (Q, Q) matmuls on the MXU.
 * Gating simplification vs the paper: input gate is sigmoid (GLA-style)
   rather than exp-with-stabilizer — same compute/memory character, simpler
   numerics; sLSTM keeps the paper's exp gating + m-stabilizer faithfully.
 * sLSTM is inherently sequential (recurrent h-mixing); it runs as a
   lax.scan over time — this is the arch's nature, not an implementation gap.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig, XLSTMConfig
from repro.parallel.axes import constrain
from repro.utils import scan as uscan


# ------------------------------------------------------------------ mLSTM ---


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    xc = cfg.xlstm
    din = int(xc.proj_factor_mlstm * cfg.d_model)
    nh = xc.n_heads
    din -= din % nh
    return din, nh, din // nh


def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    din, nh, hd = _mlstm_dims(cfg)
    keys = jax.random.split(key, 7)
    return {
        "up": L.dense_init(keys[0], (d, 2 * din), fan_in=d),
        "wq": L.dense_init(keys[1], (din, nh, hd), fan_in=din),
        "wk": L.dense_init(keys[2], (din, nh, hd), fan_in=din),
        "wv": L.dense_init(keys[3], (din, nh, hd), fan_in=din),
        "wif": L.dense_init(keys[4], (din, nh, 2), fan_in=din),
        "fgate_bias": jnp.full((nh,), 3.0, jnp.float32),  # start remembering
        "down": L.dense_init(keys[5], (din, d), fan_in=din),
    }


def _mlstm_gates(params, xm):
    """xm (B, S, din) -> q, k, v (B, S, nh, hd) and log_f, i (B, S, nh) fp32."""
    q = jnp.einsum("bsd,dhk->bshk", xm, params["wq"].astype(xm.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xm, params["wk"].astype(xm.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xm, params["wv"].astype(xm.dtype))
    gates = jnp.einsum("bsd,dhg->bshg", xm, params["wif"].astype(xm.dtype))
    gates = gates.astype(jnp.float32)
    i = jax.nn.sigmoid(gates[..., 0])
    log_f = jax.nn.log_sigmoid(gates[..., 1] + params["fgate_bias"])
    return q, k, v, log_f, i


def mlstm_block(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    out, _ = mlstm_prefill(params, cfg, x)
    return out


def mlstm_prefill(params: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, dict]:
    """Training/prefill form.  x (B, S, d) -> ((B, S, d), decode cache)."""
    xc: XLSTMConfig = cfg.xlstm
    b, s, _ = x.shape
    din, nh, hd = _mlstm_dims(cfg)
    xd = x.astype(L.ACT_DTYPE)
    xz = jnp.einsum("bsd,de->bse", xd, params["up"].astype(xd.dtype))
    xz = constrain(xz, "batch", "seq", "inner")
    xm, z = jnp.split(xz, 2, axis=-1)

    q, k, v, log_f, i_gate = _mlstm_gates(params, xm)
    scale = 1.0 / jnp.sqrt(hd)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    qc = min(xc.chunk, s)
    nc = -(-s // qc)
    s_pad = nc * qc
    if s_pad != s:
        # identity padding: log_f=0 (f=1), i=0 -> state passes through
        padw = ((0, 0), (0, s_pad - s)) + ((0, 0),) * 2
        qf = jnp.pad(qf, padw)
        kf = jnp.pad(kf, padw)
        vf = jnp.pad(vf, padw)
        log_f = jnp.pad(log_f, padw[:3])
        i_gate = jnp.pad(i_gate, padw[:3])

    def reshape_c(a):
        return jnp.moveaxis(a.reshape(b, nc, qc, *a.shape[2:]), 1, 0)

    qs, ks, vs = reshape_c(qf), reshape_c(kf), reshape_c(vf)
    lfs, igs = reshape_c(log_f), reshape_c(i_gate)

    c0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, nh, hd), jnp.float32)

    def step(carry, inp):
        c_prev, n_prev = carry
        qi, ki, vi, lf, ig = inp                     # (B, Q, nh, ...)
        clf = jnp.cumsum(lf, axis=1)                 # (B, Q, nh)
        # intra-chunk: W[t, u] = exp(clf_t - clf_u) * i_u  for u <= t
        rel = clf[:, :, None, :] - clf[:, None, :, :]          # (B, Q, Q, nh)
        tri = jnp.tril(jnp.ones((qc, qc), bool))
        w = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0) * ig[:, None, :, :]
        scores = jnp.einsum("bthk,buhk->btuh", qi, ki) * w
        y_intra = jnp.einsum("btuh,buhk->bthk", scores, vi)
        n_intra = jnp.einsum("btuh,buhk->bthk", w, ki * jnp.ones_like(ki))
        # inter-chunk
        decay_t = jnp.exp(clf)                                   # (B, Q, nh)
        y_inter = jnp.einsum("bthk,bhkl->bthl", qi * decay_t[..., None], c_prev)
        n_inter = n_prev[:, None] * decay_t[..., None]
        y = y_intra + y_inter
        n_t = n_intra + n_inter
        denom = jnp.abs(jnp.einsum("bthk,bthk->bth", qi, n_t))
        h = y / jnp.maximum(denom, 1.0)[..., None]
        # state update to end of chunk
        tail = clf[:, -1:, :] - clf                              # (B, Q, nh) >= 0? no: clf_Q - clf_u
        wk_tail = jnp.exp(tail) * ig                             # (B, Q, nh)
        c_new = c_prev * jnp.exp(clf[:, -1])[..., None, None] + jnp.einsum(
            "buhk,buhl,buh->bhkl", ki, vi, wk_tail
        )
        n_new = n_prev * jnp.exp(clf[:, -1])[..., None] + jnp.einsum(
            "buhk,buh->bhk", ki, wk_tail
        )
        return (c_new, n_new), h

    (c_f, n_f), hs = uscan.scan(step, (c0, n0), (qs, ks, vs, lfs, igs))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s_pad, din)[:, :s].astype(xd.dtype)
    out = h * jax.nn.silu(z.astype(jnp.float32)).astype(xd.dtype)
    out = jnp.einsum("bse,ed->bsd", out, params["down"].astype(xd.dtype))
    return out, {"c": c_f, "n": n_f}


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    din, nh, hd = _mlstm_dims(cfg)
    return {
        "c": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
    }


def mlstm_decode_step(params, cfg: ModelConfig, x: jax.Array, cache: dict):
    """x (B, 1, d) -> (B, 1, d); O(1) state update."""
    din, nh, hd = _mlstm_dims(cfg)
    xd = x.astype(L.ACT_DTYPE)
    xz = jnp.einsum("bsd,de->bse", xd, params["up"].astype(xd.dtype))
    xz = constrain(xz, "batch", "seq", "inner")
    xm, z = jnp.split(xz, 2, axis=-1)
    q, k, v, log_f, i_gate = _mlstm_gates(params, xm)
    qf = q[:, 0].astype(jnp.float32) / jnp.sqrt(hd)             # (B, nh, hd)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    f = jnp.exp(log_f[:, 0])[..., None]                          # (B, nh, 1)
    i = i_gate[:, 0][..., None]
    c = cache["c"] * f[..., None] + i[..., None] * kf[..., :, None] * vf[..., None, :]
    n = cache["n"] * f + i * kf
    y = jnp.einsum("bhk,bhkl->bhl", qf, c)
    denom = jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n))
    h = (y / jnp.maximum(denom, 1.0)[..., None]).reshape(x.shape[0], 1, din)
    out = h.astype(xd.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(xd.dtype)
    return jnp.einsum("bse,ed->bsd", out, params["down"].astype(xd.dtype)), {
        "c": c,
        "n": n,
    }


# ------------------------------------------------------------------ sLSTM ---


def _slstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    xc = cfg.xlstm
    din = int(xc.proj_factor_slstm * cfg.d_model)
    nh = xc.n_heads
    din -= din % nh
    return din, nh, din // nh


def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    din, nh, hd = _slstm_dims(cfg)
    keys = jax.random.split(key, 4)
    return {
        "up": L.dense_init(keys[0], (d, din), fan_in=d),
        "wx": L.dense_init(keys[1], (din, 4, din), fan_in=din),
        "r": L.dense_init(keys[2], (nh, hd, 4, hd), fan_in=hd),
        "bias": jnp.zeros((4, din), jnp.float32),
        "down": L.dense_init(keys[3], (din, d), fan_in=din),
    }


def _slstm_scan(params, cfg, gx, h0, c0, n0, m0):
    """gx: (B, S, 4, din) fp32 input-side gate pre-activations."""
    din, nh, hd = _slstm_dims(cfg)
    b = gx.shape[0]
    r = params["r"].astype(jnp.float32)

    def step(carry, g_t):
        h, c, n, m = carry                             # each (B, din)
        hh = h.reshape(b, nh, hd)
        rec = jnp.einsum("bhk,hkgl->bghl", hh, r).reshape(b, 4, din)
        raw = g_t + rec + params["bias"]
        z = jnp.tanh(raw[:, 0])
        i_t = raw[:, 1]
        f_t = raw[:, 2]
        o = jax.nn.sigmoid(raw[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)              # exp-gate stabilizer
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + m - m_new)
        c_new = f_p * c + i_p * z
        n_new = f_p * n + i_p
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    (h, c, n, m), hs = lax.scan(step, (h0, c0, n0, m0), jnp.moveaxis(gx, 1, 0))
    return jnp.moveaxis(hs, 0, 1), (h, c, n, m)


def slstm_block(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    out, _ = slstm_prefill(params, cfg, x)
    return out


def slstm_prefill(params: dict, cfg: ModelConfig, x: jax.Array) -> tuple[jax.Array, dict]:
    b, s, _ = x.shape
    din, _, _ = _slstm_dims(cfg)
    xd = x.astype(L.ACT_DTYPE)
    xu = jnp.einsum("bsd,de->bse", xd, params["up"].astype(xd.dtype))
    gx = jnp.einsum("bse,egf->bsgf", xu, params["wx"].astype(xd.dtype)).astype(jnp.float32)
    zeros = jnp.zeros((b, din), jnp.float32)
    hs, (h, c, n, m) = _slstm_scan(params, cfg, gx, zeros, zeros, zeros, zeros - 10.0)
    out = jnp.einsum("bse,ed->bsd", hs.astype(xd.dtype), params["down"].astype(xd.dtype))
    return out, {"h": h, "c": c, "n": n, "m": m}


def init_slstm_cache(cfg: ModelConfig, batch: int) -> dict:
    din, _, _ = _slstm_dims(cfg)
    z = jnp.zeros((batch, din), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z - 10.0}


def slstm_decode_step(params, cfg: ModelConfig, x: jax.Array, cache: dict):
    xd = x.astype(L.ACT_DTYPE)
    xu = jnp.einsum("bsd,de->bse", xd, params["up"].astype(xd.dtype))
    gx = jnp.einsum("bse,egf->bsgf", xu, params["wx"].astype(xd.dtype)).astype(jnp.float32)
    hs, (h, c, n, m) = _slstm_scan(
        params, cfg, gx, cache["h"], cache["c"], cache["n"], cache["m"]
    )
    out = jnp.einsum("bse,ed->bsd", hs.astype(xd.dtype), params["down"].astype(xd.dtype))
    return out, {"h": h, "c": c, "n": n, "m": m}
