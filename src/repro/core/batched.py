"""Batched, kernel-backed active search — the Pallas execution path.

The jnp path (`active_search.py`) runs the paper's per-query loop under
`vmap`: each query separately counts circles via `lax.switch` over pyramid
levels, gathers its CSR window row-by-row, and ranks with `lax.top_k`.  This
module executes the SAME algorithm batch-at-a-time on the purpose-built
Pallas kernels so the hot path is MXU/VPU-shaped:

  1. Eq.-1 radius adaptation for the whole batch via the LEVEL-SCHEDULED
     `kernels.ops.tile_count_multilevel` — ONE pallas_call per iteration
     that scalar-prefetches each query's (level, window) pair and DMAs its
     circle from the correct pyramid level of the flattened tile array
     (GridIndex.pyr_tiles), instead of counting every level and selecting
     from an (L, B, C) stack (the PR-1 L-fold overcount, kept as
     `batched_counts_stacked` for benchmarking);
  2. the CSR window gather as ONE batched (B, w*row_cap) advanced-index
     gather instead of B*w dynamic_slices;
  3. re-ranking with the fused `kernels.ops.candidate_topk` distance+top-k
     kernel (interpret-mode on CPU, Mosaic on TPU) instead of per-query
     `lax.top_k`.

`search`/`classify` also take `chunk_size=`: serve-scale batches stream
through fixed-size kernel invocations (one static shape, bounded VMEM)
instead of materializing giant per-batch intermediates.

Semantics are bit-for-bit identical to the jnp path (the kernels share their
oracles' contracts; see tests/test_batched_backend.py).  This module is the
implementation behind the `pallas` backend of the `repro.api` registry —
hold an `ActiveSearcher` with `ExecutionPlan(backend="pallas")` instead of
calling these entry points directly (the old `active_search.search(
backend=...)` kwarg path survives only as a deprecation shim).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import projection as proj_lib
from repro.core import pyramid as pyr
from repro.core.active_search import (
    Candidates,
    SearchResult,
    _metric_dist,
    majority_vote,
    padded_csr,
    run_chunked,
    window_spans,
)
from repro.core.grid import GridConfig, GridIndex, flatten_pyramid_tiles
from repro.kernels import ops


# --------------------------------------------------------------- counting ----


def batched_counts(
    index: GridIndex,
    cfg: GridConfig,
    q_grid: jax.Array,
    radii: jax.Array,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-class circle counts (B, C) for a batch of queries/radii.

    Pyramid counter: ONE `ops.tile_count_multilevel` pallas_call — each
    query's `level_for_radius` level and window origin are scalar-prefetched,
    so every grid program DMAs its circle from the correct pyramid level of
    the flattened tile array.  No (L, B, C) stack, no L-fold overcount.
    """
    if cfg.counter == "sat":
        from repro.core import integral as integral_lib

        return jax.vmap(lambda q, r: integral_lib.count_linf(index.sat, q, r))(
            q_grid, radii
        )

    levels = pyr.level_for_radius(radii, cfg)  # (B,) int32
    tiles = index.pyr_tiles
    if tiles is None:  # index predates the flattened layout — build it here
        tiles = flatten_pyramid_tiles(index.pyramid, cfg.tile)
    return ops.tile_count_multilevel(
        tiles, q_grid, radii.astype(jnp.float32), levels, cfg.tile,
        cfg.level_nblks, metric=cfg.metric, interpret=interpret,
    )


def batched_counts_stacked(
    index: GridIndex,
    cfg: GridConfig,
    q_grid: jax.Array,
    radii: jax.Array,
    interpret: bool | None = None,
) -> jax.Array:
    """The PR-1 counting path: `ops.tile_count` over EVERY level, then a
    take_along_axis select from the (L, B, C) stack.  L-fold more kernel
    work than `batched_counts`; kept as the benchmark baseline and as a
    second oracle for the level-scheduled kernel."""
    if cfg.counter == "sat":
        return batched_counts(index, cfg, q_grid, radii)

    levels = pyr.level_for_radius(radii, cfg)  # (B,) int32
    per_level = jnp.stack(
        [
            ops.tile_count(
                arr, q_grid, radii.astype(jnp.float32), 1 << lv, cfg.tile,
                metric=cfg.metric, interpret=interpret,
            )
            for lv, arr in enumerate(index.pyramid)
        ],
        axis=0,
    )  # (L, B, C)
    return jnp.take_along_axis(per_level, levels[None, :, None], axis=0)[0]


def radius_search_batched(
    index: GridIndex,
    cfg: GridConfig,
    q_grid: jax.Array,
    k: int,
    interpret: bool | None = None,
) -> dict[str, jax.Array]:
    """Eq. 1 for a whole batch at once — all (B,) state arrays advance in one
    `while_loop` whose body is a SINGLE level-scheduled tile_count_multilevel
    call (one pallas_call per iteration, not one per pyramid level).

    Lane-for-lane identical to `vmap(pyramid.radius_search)`: finished lanes
    freeze (masked update) while the rest keep iterating.
    """
    b = q_grid.shape[0]
    k_hi = jnp.int32(max(k, math.ceil(k * cfg.k_slack)))
    r_max = jnp.int32(cfg.max_radius)
    sentinel = r_max + 1

    def cond(state):
        t, _r, done, _best = state
        return jnp.any(jnp.logical_and(t < cfg.max_iters, jnp.logical_not(done)))

    def body(state):
        t, r, done, best = state
        active = jnp.logical_and(t < cfg.max_iters, jnp.logical_not(done))
        n = batched_counts(index, cfg, q_grid, r, interpret).sum(axis=-1)  # (B,)
        hit = jnp.logical_and(n >= k, n <= k_hi)
        best_new = jnp.where(n >= k, jnp.minimum(best, r), best)
        ratio = jnp.sqrt(k / jnp.maximum(n, 1).astype(jnp.float32))
        r_new = jnp.round(r.astype(jnp.float32) * ratio).astype(jnp.int32)
        r_new = jnp.where(n == 0, r * 2, r_new)
        r_new = jnp.clip(r_new, 1, r_max)
        r_new = jnp.where(
            jnp.logical_and(r_new == r, jnp.logical_not(hit)),
            r + jnp.where(n < k, 1, -1),
            r_new,
        )
        r_next = jnp.where(hit, r, jnp.clip(r_new, 1, r_max))
        return (
            jnp.where(active, t + 1, t),
            jnp.where(active, r_next, r),
            jnp.where(active, hit, done),
            jnp.where(active, best_new, best),
        )

    r0 = jnp.full((b,), jnp.clip(jnp.int32(cfg.r0), 1, r_max), jnp.int32)
    state0 = (
        jnp.zeros((b,), jnp.int32),
        r0,
        jnp.zeros((b,), bool),
        jnp.full((b,), sentinel, jnp.int32),
    )
    t, r, converged, best = jax.lax.while_loop(cond, body, state0)

    r_final = jnp.where(converged, r, jnp.where(best <= r_max, best, r_max))
    n_final = batched_counts(index, cfg, q_grid, r_final, interpret).sum(axis=-1)
    return {
        "radius": r_final,
        "count": n_final,
        "iters": t,
        "converged": converged,
    }


# ----------------------------------------------------------------- gather ----


def gather_candidates_batched(
    index: GridIndex, cfg: GridConfig, q_grid: jax.Array
) -> Candidates:
    """CSR window gather for the whole batch as ONE advanced-index gather.

    Same span math as the per-query path (`active_search.window_spans` /
    `padded_csr`), but the (B, w, row_cap) index tensor is materialized up
    front so the candidate records come back in a single (B, w*row_cap)
    gather per field.
    """
    w, rcap = cfg.window, cfg.row_cap
    b = q_grid.shape[0]
    pts, crd, lab, ids, n, n_pad = padded_csr(index, rcap)
    start, end = window_spans(index, cfg, q_grid)                   # (B, w)

    s_cl = jnp.clip(start, 0, max(n_pad - rcap, 0))                 # (B, w)
    j = s_cl[:, :, None] + jnp.arange(rcap, dtype=jnp.int32)        # (B, w, rcap)
    ok = (j >= start[:, :, None]) & (j < end[:, :, None]) & (j < n)

    flat = j.reshape(b, w * rcap)
    return Candidates(
        points=jnp.take(pts, flat, axis=0),      # (B, w*rcap, d)
        coords=jnp.take(crd, flat, axis=0),      # (B, w*rcap, 2)
        labels=jnp.take(lab, flat, axis=0),      # (B, w*rcap)
        ids=jnp.take(ids, flat, axis=0),         # (B, w*rcap)
        valid=ok.reshape(b, w * rcap),
    )


# ------------------------------------------------------------------ topk -----


def _topk_batched(
    cand: Candidates,
    rank_points: jax.Array,   # (B, C, rd) — vectors the kernel ranks by
    rank_queries: jax.Array,  # (B, rd)
    k: int,
    cfg: GridConfig,
    stats: dict[str, jax.Array],
    truncated: jax.Array,
    interpret: bool | None,
) -> SearchResult:
    """Fused distance + top-k via `ops.candidate_topk`, then record assembly.

    d_chunk is rounded up to the full feature dim so the kernel reduces each
    candidate in one accumulation step — bit-identical to the jnp path's
    single-sum distances (multi-chunk accumulation would reassociate the
    float32 sum).  On TPU with very large d, cap d_chunk and accept the
    reassociation.
    """
    rd = rank_points.shape[-1]
    outd, outi = ops.candidate_topk(
        rank_points,
        cand.valid,
        rank_queries,
        k,
        metric=cfg.metric,
        d_chunk=max(rd, 1),
        interpret=interpret,
    )
    sel_valid = jnp.isfinite(outd)
    idx = jnp.maximum(outi, 0)
    take = lambda a: jnp.take_along_axis(a, idx, axis=1)
    return SearchResult(
        ids=jnp.where(sel_valid, take(cand.ids), -1),
        dists=outd.astype(jnp.float32),
        labels=jnp.where(sel_valid, take(cand.labels), -1),
        valid=sel_valid,
        radius=stats["radius"],
        count=stats["count"],
        iters=stats["iters"],
        converged=stats["converged"],
        truncated=truncated,
    )


# -------------------------------------------------------------- entry points -


@partial(jax.jit, static_argnames=("cfg", "k", "mode", "interpret"))
def _search_impl(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    interpret: bool | None = None,
) -> SearchResult:
    q_grid = proj_lib.to_grid_coords(index.proj, queries, cfg.grid_size)  # (B, 2)
    stats = radius_search_batched(index, cfg, q_grid, k, interpret)
    r = stats["radius"]
    truncated = (2 * r + 1) > jnp.int32(cfg.window)

    cand = gather_candidates_batched(index, cfg, q_grid)
    if mode == "paper":
        centers = jnp.floor(cand.coords) + 0.5                  # (B, C, 2)
        gd = _metric_dist(centers, q_grid[:, None, :], cfg.metric)
        in_circle = gd <= r[:, None].astype(jnp.float32)
        cand = cand._replace(valid=cand.valid & in_circle)
        return _topk_batched(
            cand, centers, q_grid, k, cfg, stats, truncated, interpret
        )

    return _topk_batched(
        cand,
        cand.points,
        queries.astype(jnp.float32),
        k,
        cfg,
        stats,
        truncated,
        interpret,
    )


def search(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    interpret: bool | None = None,
    chunk_size: int | None = None,
) -> SearchResult:
    """Batched kernel-backed active search: queries (B, d) -> SearchResult
    with leading B.  Same result contract as the facade's
    `ActiveSearcher.search` (repro.api), which is how callers should reach
    this path (`ExecutionPlan(backend="pallas")`).

    chunk_size streams the batch through fixed-size kernel invocations (one
    static shape, bounded VMEM) — results are bit-identical for any value.
    """
    return run_chunked(
        lambda q: _search_impl(index, cfg, q, k, mode, interpret),
        queries,
        chunk_size,
    )


@partial(jax.jit, static_argnames=("cfg", "k", "mode", "interpret"))
def _classify_impl(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    interpret: bool | None = None,
) -> jax.Array:
    if cfg.n_classes <= 0:
        raise ValueError("classify() needs an index built with n_classes > 0")

    q_grid = proj_lib.to_grid_coords(index.proj, queries, cfg.grid_size)

    if mode == "paper":
        stats = radius_search_batched(index, cfg, q_grid, k, interpret)
        counts = batched_counts(index, cfg, q_grid, stats["radius"], interpret)
        return jnp.argmax(counts, axis=-1).astype(jnp.int32)

    res = _search_impl(index, cfg, queries, k, mode="refined", interpret=interpret)
    refined = majority_vote(res.labels, res.valid, cfg.n_classes)

    # same graceful degradation as the jnp path, but counted by the kernel
    fallback = jnp.argmax(
        batched_counts(index, cfg, q_grid, res.radius, interpret), axis=-1
    ).astype(jnp.int32)
    short = jnp.sum(res.valid.astype(jnp.int32), axis=1) < k
    return jnp.where(short | res.truncated, fallback, refined)


def classify(
    index: GridIndex,
    cfg: GridConfig,
    queries: jax.Array,
    k: int,
    mode: str = "refined",
    interpret: bool | None = None,
    chunk_size: int | None = None,
) -> jax.Array:
    """Batched kNN classification — same result contract as the facade's
    `ActiveSearcher.classify` (repro.api), with every count pass going
    through the level-scheduled tile_count_multilevel kernel."""
    return run_chunked(
        lambda q: _classify_impl(index, cfg, q, k, mode, interpret),
        queries,
        chunk_size,
    )
