"""Pallas TPU kernel: blocked exact kNN (the paper's baseline, done right).

Streaming formulation so the (B, N) distance matrix never exists in HBM:
grid = (B-blocks, N-blocks); each step computes one (bq, bn) distance block
on the MXU (||q||^2 - 2 q.x + ||x||^2) and folds it into a running top-k that
lives in VMEM scratch across the sequential N-block axis — the same pattern
flash-attention uses for its running softmax.

MXU alignment: bq and bn default to 128/512; d is the contraction dim.
Validated with interpret=True against ref.brute_knn.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    q_ref,    # (bq, d) float32
    x_ref,    # (bn, d) float32
    outd_ref,  # (bq, k) float32
    outi_ref,  # (bq, k) int32
    bestd_ref,  # scratch (bq, k) float32
    besti_ref,  # scratch (bq, k) int32
    *,
    k: int,
    bn: int,
    nn: int,
    n: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bestd_ref[...] = jnp.full_like(bestd_ref, jnp.inf)
        besti_ref[...] = jnp.full_like(besti_ref, -1)

    q = q_ref[...]
    x = x_ref[...]
    qq = jnp.sum(q * q, axis=1, keepdims=True)            # (bq, 1)
    xx = jnp.sum(x * x, axis=1)[None, :]                  # (1, bn)
    cross = jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    d = jnp.sqrt(jnp.maximum(qq - 2.0 * cross + xx, 0.0))  # (bq, bn)

    ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(ids < n, d, jnp.inf)

    cat_d = jnp.concatenate([bestd_ref[...], d], axis=1)   # (bq, k + bn)
    cat_i = jnp.concatenate([besti_ref[...], ids], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, cat_d.shape, 1)
    new_d, new_i = [], []
    for _ in range(k):
        m = jnp.min(cat_d, axis=1)                         # (bq,)
        am = jnp.argmin(cat_d, axis=1)                     # (bq,)
        new_d.append(m)
        new_i.append(jnp.take_along_axis(cat_i, am[:, None], axis=1)[:, 0])
        cat_d = jnp.where(col == am[:, None], jnp.inf, cat_d)
    bestd_ref[...] = jnp.stack(new_d, axis=1)
    besti_ref[...] = jnp.stack(new_i, axis=1)

    @pl.when(j == nn - 1)
    def _emit():
        outd_ref[...] = bestd_ref[...]
        outi_ref[...] = jnp.where(
            jnp.isfinite(bestd_ref[...]), besti_ref[...], -1
        )


@functools.partial(
    jax.jit, static_argnames=("k", "block_q", "block_n", "interpret")
)
def brute_knn(
    queries: jax.Array,  # (B, d)
    points: jax.Array,   # (N, d)
    k: int,
    block_q: int = 128,
    block_n: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Contract identical to ref.brute_knn (ids of padded rows are -1/inf)."""
    q = queries.astype(jnp.float32)
    x = points.astype(jnp.float32)
    b, d = q.shape
    n = x.shape[0]
    bq = min(block_q, b)
    bn = min(block_n, n)
    nb = -(-b // bq)
    nn = -(-n // bn)
    q = jnp.pad(q, ((0, nb * bq - b), (0, 0)))
    x = jnp.pad(x, ((0, nn * bn - n), (0, 0)))

    kernel = functools.partial(_kernel, k=k, bn=bn, nn=nn, n=n)
    outd, outi = pl.pallas_call(
        kernel,
        grid=(nb, nn),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * bq, k), jnp.float32),
            jax.ShapeDtypeStruct((nb * bq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, x)
    return outd[:b], outi[:b]
