"""Per-query adaptive radius schedule + early exit (ISSUE 6).

Three contracts pinned here:

1. EARLY EXIT IS FREE: the masked Eq.-1 loop (converged lanes skip their
   tile DMAs, post-loop recount touches only fallback lanes) is lane-for-lane
   BIT-IDENTICAL to the always-on loop — across skewed/uniform/grid-corner
   densities, both metrics, chunked and unchunked.
2. ADAPTIVE SEEDING IS A SCHEDULE CHANGE ONLY: `pyramid.seed_radius` starts
   each lane from its own local-density estimate; the batched path matches
   the vmapped jnp oracle on every stat, and results still follow whatever
   radius the schedule converges to.
3. THE OSCILLATION ESCAPE TERMINATES: a lane stuck with n > k_hi at r == 1
   (Eq. 1 rounds to 0, the stall-escape decrements into the clip) must run
   to max_iters with converged=False and a sane best fallback — never spin
   past the cap or return a zero/negative radius.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as hst

from repro.core import batched
from repro.core import projection as proj_lib
from repro.core import pyramid as pyr
from repro.core.grid import GridConfig, build_index
from repro.kernels import ops


K = 8


def _make(points, metric="l2", grid=128, r0=8, k_slack=2.0, n_classes=0,
          labels=None):
    pts = jnp.asarray(points, jnp.float32)
    cfg = GridConfig(grid_size=grid, tile=16, window=48, row_cap=64, r0=r0,
                     k_slack=k_slack, metric=metric, n_classes=n_classes)
    proj = proj_lib.identity_projection(pts)
    return cfg, proj, build_index(pts, cfg, proj, labels=labels)


def _densities(rng):
    """Named point sets spanning the densities the mask must survive:
    a skewed cluster (most lanes converge at different iterations), a
    uniform field (lanes converge together), and grid-corner pileups
    (clamped windows + duplicate cover tiles)."""
    skewed = np.concatenate([
        rng.normal(0.0, 0.08, size=(700, 2)),
        rng.uniform(-3, 3, size=(300, 2)),
    ])
    uniform = rng.uniform(-3, 3, size=(1000, 2))
    corners = np.concatenate([
        rng.normal([-3, -3], 0.05, size=(400, 2)),
        rng.normal([3, 3], 0.05, size=(400, 2)),
        rng.uniform(-3, 3, size=(200, 2)),
    ])
    return {"skewed": skewed, "uniform": uniform, "corners": corners}


def _stats_equal(a, b, msg=""):
    for key in ("radius", "count", "iters", "converged"):
        np.testing.assert_array_equal(
            np.asarray(a[key]), np.asarray(b[key]), err_msg=f"{msg}:{key}"
        )


# -------------------------------------------------- masked-kernel contract ---


def test_masked_kernel_matches_unmasked_rows(rng):
    """tile_count_multilevel with an `active` mask: live rows bit-identical
    to the unmasked call, parked rows exactly 0 — random masks plus the
    all-live / all-parked extremes (the all-parked grid still runs; every
    program aliases lane 0's tiles and the output is discarded)."""
    cfg, proj, index = _make(rng.normal(size=(900, 2)))
    b = 24
    q = jnp.asarray(rng.uniform(5, cfg.grid_size - 5, size=(b, 2)), jnp.float32)
    radii = jnp.asarray(rng.integers(1, cfg.max_radius, size=b), jnp.float32)
    levels = pyr.level_for_radius(radii, cfg)
    args = (index.pyr_tiles, q, radii, levels, cfg.tile, cfg.level_nblks)
    base = ops.tile_count_multilevel(*args, metric=cfg.metric)
    masks = [
        jnp.asarray(rng.integers(0, 2, size=b).astype(bool)),
        jnp.ones((b,), bool),
        jnp.zeros((b,), bool),
    ]
    for mask in masks:
        got = ops.tile_count_multilevel(*args, metric=cfg.metric, active=mask)
        np.testing.assert_array_equal(
            np.asarray(got[np.asarray(mask)]),
            np.asarray(base[np.asarray(mask)]),
        )
        assert (np.asarray(got[~np.asarray(mask)]) == 0).all()


def test_batched_counts_mask_passthrough(rng):
    cfg, proj, index = _make(rng.normal(size=(500, 2)))
    q = jnp.asarray(rng.uniform(10, 100, size=(8, 2)), jnp.float32)
    radii = jnp.asarray(rng.integers(1, 30, size=8), jnp.int32)
    mask = jnp.asarray([True, False] * 4)
    full = batched.batched_counts(index, cfg, q, radii)
    got = batched.batched_counts(index, cfg, q, radii, active=mask)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(full) * np.asarray(mask)[:, None]
    )


# ------------------------------------------- early-exit loop bit parity ------


@pytest.mark.parametrize("metric", ["l2", "l1"])
@pytest.mark.parametrize("density", ["skewed", "uniform", "corners"])
def test_early_exit_bit_parity(rng, metric, density):
    """The tentpole invariant: the masked early-exit loop returns the SAME
    radius/count/iters/converged, lane for lane, as the always-on loop AND
    as the vmapped per-query jnp oracle — with and without adaptive seeds."""
    pts = _densities(rng)[density]
    cfg, proj, index = _make(pts, metric=metric)
    q = jnp.asarray(pts[rng.choice(len(pts), 24, replace=False)], jnp.float32)
    qg = proj_lib.to_grid_coords(proj, q, cfg.grid_size)
    for adaptive in (False, True):
        oracle = jax.vmap(
            lambda g: pyr.radius_search(index, cfg, g, K, adaptive_r0=adaptive)
        )(qg)
        masked = batched.radius_search_batched(
            index, cfg, qg, K, adaptive_r0=adaptive, early_exit=True
        )
        legacy = batched.radius_search_batched(
            index, cfg, qg, K, adaptive_r0=adaptive, early_exit=False
        )
        tag = f"{density}/{metric}/adaptive={adaptive}"
        _stats_equal(masked, oracle, msg=f"{tag}:masked-vs-oracle")
        _stats_equal(masked, legacy, msg=f"{tag}:masked-vs-legacy")
        assert int(legacy["tile_dmas_skipped"]) == 0
        if bool(np.asarray(masked["converged"]).any()):
            assert int(masked["tile_dmas_skipped"]) > 0, tag


def test_early_exit_parity_survives_chunking(rng):
    """search() with chunk_size slices the batch mid-mask — results must stay
    bit-identical to the unchunked call (and to the jnp backend)."""
    from repro.core.active_search import _search_jnp

    pts = _densities(rng)["skewed"]
    cfg, proj, index = _make(pts)
    q = jnp.asarray(pts[rng.choice(len(pts), 13, replace=False)], jnp.float32)
    for adaptive in (False, True):
        ref = _search_jnp(index, cfg, q, K, "refined", adaptive)
        full = batched.search(index, cfg, q, K, adaptive_r0=adaptive)
        chunked = batched.search(index, cfg, q, K, chunk_size=4,
                                 adaptive_r0=adaptive)
        for field in ref._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(full, field)),
                np.asarray(getattr(ref, field)),
                err_msg=f"full:{field}:adaptive={adaptive}",
            )
            np.testing.assert_array_equal(
                np.asarray(getattr(chunked, field)),
                np.asarray(getattr(full, field)),
                err_msg=f"chunked:{field}:adaptive={adaptive}",
            )


def test_sat_counter_ignores_mask_but_keeps_parity(rng):
    """counter='sat' has no tile DMAs to elide: the loop must not mask (the
    skip counter stays 0) and still match the jnp oracle exactly."""
    pts = _densities(rng)["skewed"]
    pts_j = jnp.asarray(pts, jnp.float32)
    cfg = GridConfig(grid_size=128, tile=16, window=48, row_cap=64, r0=8,
                     k_slack=2.0, counter="sat")
    proj = proj_lib.identity_projection(pts_j)
    index = build_index(pts_j, cfg, proj)
    q = jnp.asarray(pts[rng.choice(len(pts), 12, replace=False)], jnp.float32)
    qg = proj_lib.to_grid_coords(proj, q, cfg.grid_size)
    oracle = jax.vmap(lambda g: pyr.radius_search(index, cfg, g, K))(qg)
    got = batched.radius_search_batched(index, cfg, qg, K)
    _stats_equal(got, oracle, msg="sat")
    assert int(got["tile_dmas_skipped"]) == 0


# --------------------------------------------------------- adaptive seeds ----


def test_seed_radius_tracks_local_density(rng):
    """Dense-region queries must seed tighter than sparse-region queries,
    every seed stays in [1, max_radius], and an empty pyramid falls back to
    cfg.r0 — the sketch can only move the START, never break the loop."""
    pts = np.concatenate([
        rng.normal(0.0, 0.05, size=(900, 2)),   # dense blob at origin
        rng.uniform(-3, 3, size=(100, 2)),      # thin background
    ])
    cfg, proj, index = _make(pts, r0=64)
    qg_dense = proj_lib.to_grid_coords(
        proj, jnp.zeros((1, 2), jnp.float32), cfg.grid_size
    )[0]
    qg_sparse = proj_lib.to_grid_coords(
        proj, jnp.asarray([[2.9, -2.9]], jnp.float32), cfg.grid_size
    )[0]
    s_dense = int(pyr.seed_radius(index, cfg, qg_dense, K))
    s_sparse = int(pyr.seed_radius(index, cfg, qg_sparse, K))
    assert 1 <= s_dense <= cfg.max_radius
    assert 1 <= s_sparse <= cfg.max_radius
    assert s_dense < s_sparse
    # empty index: no mass anywhere -> global default (projection borrowed
    # from real points; identity_projection cannot derive extents from 0)
    cfg_e = GridConfig(grid_size=128, tile=16, window=48, row_cap=64, r0=32,
                       k_slack=2.0)
    index_e = build_index(jnp.zeros((0, 2), jnp.float32), cfg_e, proj)
    assert int(pyr.seed_radius(index_e, cfg_e, qg_dense, K)) == cfg_e.r0


def test_adaptive_r0_changes_schedule_not_results(rng):
    """Refined-mode ids/dists are radius-independent by construction — the
    adaptive schedule may stop at a different radius/iteration but must
    return the same neighbors whenever both schedules converge."""
    pts = _densities(rng)["skewed"]
    cfg, proj, index = _make(pts)
    q = jnp.asarray(pts[rng.choice(len(pts), 16, replace=False)], jnp.float32)
    base = batched.search(index, cfg, q, K)
    adap = batched.search(index, cfg, q, K, adaptive_r0=True)
    both = np.asarray(base.converged) & np.asarray(adap.converged)
    np.testing.assert_array_equal(
        np.asarray(base.ids)[both], np.asarray(adap.ids)[both]
    )
    np.testing.assert_array_equal(
        np.asarray(base.dists)[both], np.asarray(adap.dists)[both]
    )


# ------------------------------------------- post-loop recount (satellite) ---


def test_final_count_reuses_hit_count(rng):
    """The n_final a converged lane reports must equal a from-scratch count
    at its final radius (the in-loop capture IS that count); fallback lanes
    are recounted for real."""
    pts = _densities(rng)["skewed"]
    cfg, proj, index = _make(pts)
    q = jnp.asarray(pts[rng.choice(len(pts), 20, replace=False)], jnp.float32)
    qg = proj_lib.to_grid_coords(proj, q, cfg.grid_size)
    st = batched.radius_search_batched(index, cfg, qg, K)
    recount = batched.batched_counts(
        index, cfg, qg, st["radius"]
    ).sum(axis=-1)
    np.testing.assert_array_equal(
        np.asarray(st["count"]), np.asarray(recount)
    )


# ------------------------------------------------- oscillation escape --------


def _osc_case(n_pts, corner, grid=32):
    """A pile of identical points at a grid corner: with k=1, k_slack=1.0
    the count at r=1 is n_pts > k_hi, Eq. 1 rounds the radius to 0, and the
    stall-escape decrement also clips back to 1 — the loop CANNOT satisfy
    the band and must terminate at max_iters."""
    span = 3.0
    pos = {
        "ll": (-span, -span), "lr": (-span, span),
        "ul": (span, -span), "ur": (span, span), "center": (0.0, 0.0),
    }[corner]
    pts = np.tile(np.asarray(pos, np.float32), (n_pts, 1))
    # identity projection needs 2-D extents: add a faint far point so the
    # grid spans more than the pile itself
    pts = np.concatenate([pts, np.asarray([[-span, span]], np.float32)])
    cfg = GridConfig(grid_size=grid, tile=8, window=8, row_cap=n_pts + 8,
                     r0=2, k_slack=1.0)
    pts_j = jnp.asarray(pts)
    proj = proj_lib.identity_projection(pts_j)
    return cfg, proj, build_index(pts_j, cfg, proj), pts_j


@pytest.mark.parametrize("corner", ["ll", "ur", "center"])
def test_oscillation_escape_terminates(rng, corner):
    cfg, proj, index, pts = _osc_case(50, corner)
    qg = proj_lib.to_grid_coords(proj, pts[:1], cfg.grid_size)
    st = pyr.radius_search(index, cfg, qg[0], 1)
    assert int(st["iters"]) == cfg.max_iters
    assert not bool(st["converged"])
    assert int(st["radius"]) >= 1            # never 0/negative
    assert int(st["count"]) >= 1             # best fallback still covers k
    stb = batched.radius_search_batched(index, cfg, qg, 1)
    _stats_equal(stb, jax.tree.map(lambda a: jnp.asarray(a)[None], st),
                 msg=corner)


@settings(max_examples=20, deadline=None)
@given(
    n_pts=hst.integers(min_value=2, max_value=300),
    corner=hst.sampled_from(["ll", "lr", "ul", "ur", "center"]),
    seed=hst.integers(min_value=0, max_value=2**31 - 1),
)
def test_oscillation_escape_property(n_pts, corner, seed):
    """Across pile sizes and grid corners: the loop always terminates within
    max_iters, the radius stays in [1, max_radius], a converged lane's count
    is inside the band, and the masked batched loop agrees lane-for-lane."""
    cfg, proj, index, pts = _osc_case(n_pts, corner)
    rng = np.random.default_rng(seed)
    q = pts[rng.integers(0, len(pts), size=3)]
    qg = proj_lib.to_grid_coords(proj, q, cfg.grid_size)
    oracle = jax.vmap(lambda g: pyr.radius_search(index, cfg, g, 1))(qg)
    got = batched.radius_search_batched(index, cfg, qg, 1)
    for key in ("radius", "count", "iters", "converged"):
        np.testing.assert_array_equal(
            np.asarray(got[key]), np.asarray(oracle[key]), err_msg=key
        )
    it = np.asarray(got["iters"])
    r = np.asarray(got["radius"])
    cv = np.asarray(got["converged"])
    n = np.asarray(got["count"])
    assert (it <= cfg.max_iters).all()
    assert ((r >= 1) & (r <= cfg.max_radius)).all()
    assert (n[cv] == 1).all()                 # k_slack=1.0: exact band
    assert (n[(~cv) & (n > 0)] >= 1).all()    # fallback covers k when it can
