"""Summed-area table (integral image) counter — beyond-paper variant.

The paper counts points in an L2 circle by scanning its pixels; our pyramid
makes that a fixed tile reduce, EXACT only at level 0.  This variant changes
the geometry instead: with an L∞ ball (an axis-aligned square — the natural
companion of the paper's own L1 remark in §3), the count is FOUR gathers into
a summed-area table, EXACT at ANY radius:

    count([x0,x1) x [y0,y1)) = S[x1,y1] - S[x0,y1] - S[x1,y0] + S[x0,y0]

No pyramid levels, no mask reduce, no radius-dependent cost at all — the
strongest possible form of the paper's "independent of N" claim on TPU
(4 HBM gathers per Eq.-1 iteration).  Enabled with GridConfig(counter="sat").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def build_sat(base: jax.Array) -> jax.Array:
    """(S, S, C) int32 counts -> (S+1, S+1, C) inclusive-prefix SAT with a
    zero border, so count_rect needs no bounds special-casing."""
    sat = jnp.cumsum(jnp.cumsum(base, axis=0), axis=1)
    return jnp.pad(sat, ((1, 0), (1, 0), (0, 0)))


def count_rect(
    sat: jax.Array, x0: jax.Array, x1: jax.Array, y0: jax.Array, y1: jax.Array
) -> jax.Array:
    """Exact per-class counts (C,) of base cells in [x0, x1) x [y0, y1).
    Bounds are int32 cell indices, clipped to the grid."""
    s = sat.shape[0] - 1
    x0 = jnp.clip(x0, 0, s)
    x1 = jnp.clip(x1, 0, s)
    y0 = jnp.clip(y0, 0, s)
    y1 = jnp.clip(y1, 0, s)
    return (
        sat[x1, y1] - sat[x0, y1] - sat[x1, y0] + sat[x0, y0]
    )


def count_linf(sat: jax.Array, q: jax.Array, r: jax.Array) -> jax.Array:
    """Per-class counts (C,) of cells whose CENTER lies within L∞ distance r
    of the continuous position q (2,) — i.e. the square [qx-r, qx+r]^2.

    A center i+0.5 is inside iff |i + 0.5 - qx| <= r, so the cell-index range
    is [ceil(qx - r - 0.5), floor(qx + r - 0.5)] inclusive."""
    rf = r.astype(jnp.float32)
    x0 = jnp.ceil(q[0] - rf - 0.5).astype(jnp.int32)
    x1 = jnp.floor(q[0] + rf - 0.5).astype(jnp.int32) + 1
    y0 = jnp.ceil(q[1] - rf - 0.5).astype(jnp.int32)
    y1 = jnp.floor(q[1] + rf - 0.5).astype(jnp.int32) + 1
    empty = (x1 <= x0) | (y1 <= y0)
    out = count_rect(sat, x0, jnp.maximum(x1, x0), y0, jnp.maximum(y1, y0))
    return jnp.where(empty, jnp.zeros_like(out), out)
