"""dbrx-132b [moe] — 16 experts top-4, fine-grained
(hf:databricks/dbrx-base; unverified).

40L d_model=6144 48H (GQA kv=8) expert d_ff=10752 vocab=100352, MoE all layers.
long_500k: SKIP (pure full attention)."""

from repro.models.config import ModelConfig, MoEConfig, ParallelismPolicy

LONG_CONTEXT = "skip"

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=4, d_expert=10752, group_size=512),
    moe_layers=(True,),
    # accum=16 keeps the 40L x 6144 activations inside 16 GiB HBM.
    policy=ParallelismPolicy(remat="full", scan_layers=True, accum=16),
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    moe=MoEConfig(n_experts=4, top_k=4, d_expert=128, group_size=64),
    moe_layers=(True,),
)
