"""GridIndex: the paper's "image" of the data set, built TPU-natively.

The paper rasterizes N points onto a G x G image whose pixels hold point
counts (one image per class for classification).  We keep that structure but
build it with sort-based bucketization (no serialized scatters):

  cell_id = quantize(project(x));  order = argsort(cell_id);
  offsets = searchsorted(cell_id[order], arange(G*G + 1))

which yields a CSR layout: points of cell c are `points_sorted[offsets[c] :
offsets[c + 1]]`.  Base-level counts are `diff(offsets)`; a count PYRAMID
(mip chain) on top gives O(1) circle counts at any radius (pyramid.py).

Everything here is a pytree of arrays; static knobs live in `GridConfig`
(frozen dataclass, passed as a static argument).
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import integral as integral_lib
from repro.core import projection as proj_lib
from repro.core.projection import Projection


@dataclasses.dataclass(frozen=True)
class GridConfig:
    """Static configuration of a grid index (hashable; safe as a jit static arg)."""

    grid_size: int = 1024        # requested G (paper: 3000)
    tile: int = 16               # pyramid tile side T checked per count (VMEM-resident)
    n_classes: int = 0           # 0 = unlabeled (single count channel)
    window: int = 32             # candidate-gather window side (base cells)
    row_cap: int = 32            # max candidates gathered per window row
    r0: int = 100                # paper's initial radius (pixels)
    max_iters: int = 16          # Eq.-1 iteration cap
    k_slack: float = 1.0         # accept n in [k, k_slack * k]; 1.0 = paper-exact
    metric: str = "l2"           # "l2" | "l1" (paper discusses both)
    counter: str = "pyramid"     # "pyramid" | "sat" (exact L-inf counts, integral.py)

    def __post_init__(self):
        # level_for_radius picks the level where a T-cell window contains the
        # circle via 2**l >= 2r / (tile - 3); with tile <= 3 the (tile - 3)
        # margin vanishes and its max(tile - 3, 1) divisor would silently
        # break the containment guarantee — reject the config outright.
        if self.tile <= 3:
            raise ValueError(
                f"tile={self.tile} is too small: the pyramid window needs a "
                "positive containment margin (tile/2 - 1.5), so tile must "
                "be >= 4"
            )
        # _metric_dist and the count kernels treat ANY non-"l1" string as l2;
        # reject typos eagerly instead of silently computing l2 distances.
        if self.metric not in ("l2", "l1"):
            raise ValueError(
                f"unknown metric {self.metric!r}; expected 'l2' or 'l1'"
            )
        if self.counter not in ("pyramid", "sat"):
            raise ValueError(
                f"unknown counter {self.counter!r}; expected 'pyramid' or 'sat'"
            )
        # The radius loop used to jnp.clip(r0, 1, max_radius) silently, so a
        # typo'd r0 (0, negative, or wider than the countable max) ran with a
        # DIFFERENT start radius than configured.  Reject it here, like the
        # tile/metric/counter checks above.
        if self.r0 <= 0:
            raise ValueError(
                f"r0={self.r0} must be a positive start radius (pixels)"
            )
        if self.r0 > self.max_radius:
            raise ValueError(
                f"r0={self.r0} exceeds max_radius={self.max_radius} (the "
                f"largest radius countable from the top pyramid tile for "
                f"grid_size={self.grid_size}, tile={self.tile})"
            )

    @property
    def n_channels(self) -> int:
        return max(self.n_classes, 1)

    @property
    def levels(self) -> int:
        """Number of pyramid levels so the TOP level is exactly `tile` wide."""
        return max(1, math.ceil(math.log2(max(self.grid_size, self.tile) / self.tile)) + 1)

    @property
    def padded_size(self) -> int:
        """G padded so padded_size == tile * 2**(levels-1) (clean mip chain)."""
        return self.tile * (1 << (self.levels - 1))

    @property
    def max_radius(self) -> int:
        """Any radius up to this is countable from the top pyramid tile."""
        return self.padded_size

    @property
    def max_candidates(self) -> int:
        return self.window * self.row_cap

    @property
    def level_nblks(self) -> tuple[int, ...]:
        """Per-level T-block counts S_l // tile — static layout of the
        flattened tile array consumed by kernels.tile_count_multilevel."""
        return tuple(1 << (self.levels - 1 - l) for l in range(self.levels))


class GridIndex(NamedTuple):
    """The built index.  All arrays; shardable along the points axis (N)."""

    proj: Projection          # grid-space projection + extents
    points_sorted: jax.Array  # (N, d) float32 — original points, CSR order
    coords_sorted: jax.Array  # (N, 2) float32 — continuous grid coords, CSR order
    labels_sorted: jax.Array  # (N,) int32 — class label (or 0), CSR order
    ids_sorted: jax.Array     # (N,) int32 — original (or global) point index
    offsets: jax.Array        # (padded_size**2 + 1,) int32 CSR cell offsets
    pyramid: tuple[jax.Array, ...]  # level l: (S_l, S_l, C) int32, S_l = padded/2**l
    sat: jax.Array | None = None    # (S+1, S+1, C) summed-area table (counter="sat")
    pyr_tiles: jax.Array | None = None  # (sum_l nblk_l^2, T, T, C) int32 —
    # the pyramid pre-cut into T-aligned tiles and concatenated level-major
    # (flatten_pyramid_tiles); the level-scheduled count kernel's input

    @property
    def n_points(self) -> int:
        return self.points_sorted.shape[0]


def cell_id_of(coords: jax.Array, padded_size: int) -> jax.Array:
    """Row-major flat cell id from continuous grid coords (..., 2)."""
    cell = jnp.floor(coords).astype(jnp.int32)
    return cell[..., 0] * padded_size + cell[..., 1]


def build_pyramid(base: jax.Array, levels: int) -> tuple[jax.Array, ...]:
    """Mip chain of count sums.  base: (S, S, C) int32, S = tile * 2**(levels-1)."""
    out = [base]
    cur = base
    for _ in range(levels - 1):
        s = cur.shape[0] // 2
        cur = cur.reshape(s, 2, s, 2, cur.shape[-1]).sum(axis=(1, 3))
        out.append(cur)
    return tuple(out)


def flatten_pyramid_tiles(pyramid: tuple[jax.Array, ...], tile: int) -> jax.Array:
    """Flatten a mip chain into one (sum_l nblk_l^2, T, T, C) tile array.

    Level l's (S_l, S_l, C) image becomes nblk_l^2 row-major (T, T, C)
    tiles (nblk_l = S_l // T); levels are concatenated in order, so tile
    (bx, by) of level l lives at row offset_l + bx * nblk_l + by.  This is
    the DMA-friendly layout tile_count_multilevel block-indexes into.
    """
    blocks = []
    for arr in pyramid:
        s, _, c = arr.shape
        nb = s // tile
        blocks.append(
            arr.reshape(nb, tile, nb, tile, c)
            .transpose(0, 2, 1, 3, 4)
            .reshape(nb * nb, tile, tile, c)
        )
    return jnp.concatenate(blocks, axis=0)


def build_index(
    points: jax.Array,
    cfg: GridConfig,
    proj: Projection,
    labels: jax.Array | None = None,
    ids: jax.Array | None = None,
) -> GridIndex:
    """Build the paper's image + CSR buckets + count pyramid.  jit-able.

    `ids` lets a distributed shard record GLOBAL point indices (distributed.py).
    """
    n = points.shape[0]
    g = cfg.padded_size
    coords = proj_lib.to_grid_coords(proj, points, cfg.grid_size)  # in [0, grid_size)
    cid = cell_id_of(coords, g)

    order = jnp.argsort(cid)
    cid_sorted = cid[order]
    offsets = jnp.searchsorted(cid_sorted, jnp.arange(g * g + 1, dtype=jnp.int32)).astype(
        jnp.int32
    )

    if labels is None:
        labels = jnp.zeros((n,), dtype=jnp.int32)
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)

    c = cfg.n_channels
    base = jnp.zeros((g * g, c), dtype=jnp.int32)
    chan = jnp.where(cfg.n_classes > 0, labels, 0).astype(jnp.int32)
    base = base.at[cid, chan].add(1)
    base = base.reshape(g, g, c)
    pyramid = build_pyramid(base, cfg.levels)

    return GridIndex(
        proj=proj,
        points_sorted=points[order].astype(jnp.float32),
        coords_sorted=coords[order].astype(jnp.float32),
        labels_sorted=labels[order].astype(jnp.int32),
        ids_sorted=ids[order].astype(jnp.int32),
        offsets=offsets,
        pyramid=pyramid,
        sat=integral_lib.build_sat(base) if cfg.counter == "sat" else None,
        # only the pyramid counter's pallas path reads the flat tiling;
        # batched_counts treats None as a hard error (pre-layout indexes are
        # upgraded once by ActiveSearcher.from_index, never per call)
        pyr_tiles=(
            flatten_pyramid_tiles(pyramid, cfg.tile)
            if cfg.counter == "pyramid" else None
        ),
    )


def base_counts(index: GridIndex) -> jax.Array:
    """(S, S) total base-level counts (sum over class channels)."""
    return index.pyramid[0].sum(axis=-1)


def validate_invariants(index: GridIndex, cfg: GridConfig) -> dict[str, bool]:
    """Cheap structural invariants (used by property tests, and by the
    mutable-index suite on delta-updated snapshots)."""
    n = index.n_points
    offs = index.offsets
    counts_from_offsets = offs[-1] == n
    monotone = bool(jnp.all(offs[1:] >= offs[:-1]))
    pyramid_mass = all(int(level.sum()) == n for level in index.pyramid)
    cid = cell_id_of(index.coords_sorted, cfg.padded_size)
    sorted_ok = bool(jnp.all(cid[1:] >= cid[:-1]))
    # base level agrees with the CSR bucket sizes, and every coarser level is
    # exactly the 2x2 sum of the level below it (delta updates must keep the
    # whole mip chain consistent, not just the base)
    base_ok = bool(
        jnp.all(index.pyramid[0].sum(axis=-1).reshape(-1) == offs[1:] - offs[:-1])
    )
    chain_ok = all(
        bool(jnp.all(build_pyramid(index.pyramid[lv], 2)[1] == index.pyramid[lv + 1]))
        for lv in range(len(index.pyramid) - 1)
    )
    tiles_ok = (
        index.pyr_tiles is None
        or bool(
            jnp.all(index.pyr_tiles == flatten_pyramid_tiles(index.pyramid, cfg.tile))
        )
    )
    return {
        "offsets_end_is_n": bool(counts_from_offsets),
        "offsets_monotone": monotone,
        "pyramid_mass_is_n": pyramid_mass,
        "cells_sorted": sorted_ok,
        "base_matches_offsets": base_ok,
        "pyramid_chain_consistent": chain_ok,
        "tiles_match_pyramid": tiles_ok,
    }
