"""Summed-area-table counter (beyond-paper variant): exactness + integration."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as hst

from repro.core import exact, integral
from repro.core import active_search as act
from repro.core.grid import GridConfig, build_index
from repro.core.projection import identity_projection


def test_count_rect_exact(rng):
    base = jnp.asarray(rng.integers(0, 5, size=(32, 32, 2)), jnp.int32)
    sat = integral.build_sat(base)
    for _ in range(20):
        x0, y0 = rng.integers(0, 32, 2)
        x1 = rng.integers(x0, 33)
        y1 = rng.integers(y0, 33)
        got = np.asarray(integral.count_rect(
            sat, jnp.int32(x0), jnp.int32(x1), jnp.int32(y0), jnp.int32(y1)))
        want = np.asarray(base[x0:x1, y0:y1].sum(axis=(0, 1)))
        np.testing.assert_array_equal(got, want)


@settings(max_examples=30, deadline=None)
@given(seed=hst.integers(0, 2**31 - 1))
def test_count_linf_matches_bruteforce(seed):
    rng = np.random.default_rng(seed)
    s = 24
    base = jnp.asarray(rng.integers(0, 3, size=(s, s, 1)), jnp.int32)
    sat = integral.build_sat(base)
    q = jnp.asarray(rng.uniform(0, s, size=2), jnp.float32)
    r = jnp.float32(rng.uniform(0.2, s))
    got = int(integral.count_linf(sat, q, r)[0])
    centers = np.stack(np.meshgrid(np.arange(s) + 0.5, np.arange(s) + 0.5,
                                   indexing="ij"), -1)
    inside = np.max(np.abs(centers - np.asarray(q)), axis=-1) <= float(r)
    want = int((np.asarray(base[..., 0]) * inside).sum())
    assert got == want


def test_sat_counter_end_to_end(rng):
    pts = jnp.asarray(rng.normal(size=(5000, 2)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 3, size=5000), jnp.int32)
    cfg = GridConfig(grid_size=256, tile=16, n_classes=3, window=48,
                     row_cap=48, r0=10, k_slack=2.0, counter="sat")
    idx = build_index(pts, cfg, identity_projection(pts), labels=labels)
    assert idx.sat is not None
    q = jnp.asarray(rng.normal(size=(50, 2)), jnp.float32)
    pred = act.classify(idx, cfg, q, 11)
    truth = exact.classify(q, pts, labels, 11, n_classes=3)
    acc = float(jnp.mean((pred == truth).astype(jnp.float32)))
    assert acc >= 0.9, acc


def test_sat_mass_conservation(rng):
    pts = jnp.asarray(rng.normal(size=(777, 2)), jnp.float32)
    cfg = GridConfig(grid_size=64, tile=8, window=8, row_cap=16, counter="sat",
                     r0=8)
    idx = build_index(pts, cfg, identity_projection(pts))
    assert int(idx.sat[-1, -1].sum()) == 777
