"""Core: the paper's active-search kNN as a composable JAX library."""

from repro.core.grid import GridConfig, GridIndex, build_index
from repro.core.projection import (
    Projection,
    gaussian_projection,
    identity_projection,
    pca_projection,
)
from repro.core.active_search import SearchResult, classify, search, search_one
from repro.core import exact

__all__ = [
    "GridConfig",
    "GridIndex",
    "build_index",
    "Projection",
    "identity_projection",
    "gaussian_projection",
    "pca_projection",
    "SearchResult",
    "search",
    "search_one",
    "classify",
    "exact",
]
