"""Paper Fig. 3: elapsed time vs N — original kNN grows with N, active search
is ~independent of N (the paper's headline claim).

100 query points, k=11, 3 classes.  Grid fixed while N varies, exactly as the
paper fixes its 3000x3000 image.  (grid_size is CPU-scaled; the 3000-image
setting runs in bench_accuracy.py.)
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, paper_data, timeit
from repro.core import active_search as act, exact
from repro.core.grid import GridConfig, build_index
from repro.core.projection import identity_projection

K = 11
N_QUERIES = 100


def main(
    grid_size: int = 1024,
    ns=(1_000, 4_000, 16_000, 64_000, 256_000),
    backend: str = "jnp",
    chunk_size: int | None = None,
) -> None:
    """backend="pallas" times the batched kernel pipeline instead of the vmap
    path (interpret-mode on CPU — compare on TPU for hardware numbers);
    chunk_size streams queries through fixed-size kernel invocations."""
    rng = np.random.default_rng(0)
    csv = Csv("n,backend,exact_knn_s,active_search_s,active_build_s,speedup")
    cfg = GridConfig(grid_size=grid_size, tile=16, n_classes=3, window=64,
                     row_cap=64, r0=100, k_slack=2.0)
    q, _ = paper_data(rng, N_QUERIES)

    for n in ns:
        pts, labels = paper_data(rng, n)
        proj = identity_projection(pts)
        t_build = timeit(
            lambda: build_index(pts, cfg, proj, labels=labels), repeats=3, warmup=1
        )
        idx = build_index(pts, cfg, proj, labels=labels)
        t_exact = timeit(lambda: exact.classify(q, pts, labels, K, 3), repeats=3)
        t_act = timeit(
            lambda: act.classify(idx, cfg, q, K, backend=backend,
                                 chunk_size=chunk_size),
            repeats=3,
        )
        csv.row(n, backend, f"{t_exact:.4f}", f"{t_act:.4f}", f"{t_build:.4f}",
                f"{t_exact / t_act:.2f}")

    # derived: paper claims active-search time ~independent of N
    return csv


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=["jnp", "pallas"], default="jnp")
    ap.add_argument("--grid-size", type=int, default=1024)
    ap.add_argument("--chunk-size", type=int, default=None)
    args = ap.parse_args()
    main(grid_size=args.grid_size, backend=args.backend,
         chunk_size=args.chunk_size)
